//! Criterion-lite: repeated sampling, summaries, aligned tables, CSV.
//!
//! (The offline crate set has no criterion; `cargo bench` runs our
//! harness=false binary built on this module.)

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use crate::util::stats::Summary;

/// One benchmark datapoint: a named configuration and its samples.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    pub fn new(name: impl Into<String>, samples: Vec<f64>) -> BenchResult {
        let summary = Summary::of(&samples);
        BenchResult { name: name.into(), samples, summary }
    }
}

/// Run `f` for `reps` seeded repetitions, collecting one f64 sample each.
pub fn sample(reps: u32, mut f: impl FnMut(u64) -> f64) -> Vec<f64> {
    (0..reps).map(|r| f(0xBE5C + r as u64)).collect()
}

/// A printable/serializable results table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        let _ = writeln!(out, "{}", hdr.join("  "));
        let _ =
            writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", cells.join("  "));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write as CSV under `dir` (created if needed), named `<slug>.csv`.
    pub fn write_csv(
        &self,
        dir: impl AsRef<Path>,
        slug: &str,
    ) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir.as_ref())?;
        let path = dir.as_ref().join(format!("{slug}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// Minimal JSON value builder (no serde in the offline crate set) —
/// used to emit machine-readable perf anchors like `BENCH_pr1.json`.
#[derive(Clone, Debug)]
pub enum Json {
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Num(v) => {
                if !v.is_finite() {
                    out.push_str("null");
                } else if *v == v.trunc() && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Format seconds with 3 significant figures.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}")
    } else if s >= 1e-3 {
        format!("{:.3}m", s * 1e3).replace('m', "e-3")
    } else {
        format!("{:.3}e-6", s * 1e6)
    }
}

/// Format a throughput in GiB/s.
pub fn fmt_gibs(bytes: u64, secs: f64) -> String {
    format!("{:.2}", bytes as f64 / (1u64 << 30) as f64 / secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["clients", "time_s"]);
        t.row(vec!["16".into(), "1.25".into()]);
        t.row(vec!["4096".into(), "10.5".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("clients"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    fn csv_round_trips() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("ckio_bench_test");
        let p = t.write_csv(&dir, "x").unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn sampling_is_seeded() {
        let s = sample(3, |seed| seed as f64);
        assert_eq!(s.len(), 3);
        assert_ne!(s[0], s[1]);
    }

    #[test]
    fn json_renders_compact_and_escaped() {
        let j = Json::obj(vec![
            ("bench", Json::str("svc_concurrent")),
            ("k", Json::num(8.0)),
            ("gibs", Json::num(3.25)),
            ("tags", Json::arr(vec![Json::str("a\"b"), Json::num(1.0)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"bench":"svc_concurrent","k":8,"gibs":3.25,"tags":["a\"b",1]}"#
        );
    }

    #[test]
    fn json_non_finite_becomes_null() {
        assert_eq!(Json::num(f64::NAN).render(), "null");
        assert_eq!(Json::num(f64::INFINITY).render(), "null");
    }
}
