//! Per-figure experiment drivers: one function per table/figure in the
//! paper's evaluation (see DESIGN.md §5 for the index). Each returns a
//! [`Table`] whose rows mirror the paper's series; `cargo bench` runs all
//! of them and writes CSVs under `bench_out/`.

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::{Ctx, Engine, EngineConfig};
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::{self, Time, MICROS};
use crate::amt::topology::{Pe, Placement};
use crate::apps::changa::driver::{run_changa_input, Scheme};
use crate::baselines::collective::{naive_writer_protocol_spec, NaiveWriter, EP_W_GO};
use crate::baselines::naive::{NaiveClient, EP_N_GO};
use crate::ckio::session::{ConsumerAdviceMsg, EP_CONSUMER_ADVICE};
use crate::ckio::write::WriteResult;
use crate::ckio::{
    CkIo, ConsumerPlacement, FileOptions, QosClass, ReadResult, ReaderPlacement, RetryPolicy,
    ServiceConfig, Session, SessionOptions, SessionOutcome, WriteOptions,
};
use crate::harness::bench::Table;
use crate::harness::bgwork::{BgWorker, EP_BG_START, EP_BG_STOP};
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::pfs::{FaultPlan, PfsConfig, StragglerSpec};
use crate::util::stats::Summary;
use crate::{ep_spec, send_spec};

/// Standard paper cluster: 16 nodes × 32 PEs (Bridges2 RM).
pub const PAPER_NODES: u32 = 16;
pub const PAPER_PES: u32 = 32;

fn gib(x: u64) -> u64 {
    x << 30
}
fn mib(x: u64) -> u64 {
    x << 20
}
fn gibs(bytes: u64, t: Time) -> f64 {
    bytes as f64 / (1u64 << 30) as f64 / time::to_secs(t)
}

// =====================================================================
// shared chares
// =====================================================================

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;
const EP_SESSION_FWD: Ep = 5;

/// A CkIO client that reads one slice of a shared session; element 0
/// opens the file and starts the session for everyone.
pub struct SliceReader {
    pub io: CkIo,
    pub file: crate::pfs::FileId,
    pub file_size: u64,
    pub session_offset: u64,
    pub session_bytes: u64,
    pub my_offset: u64,
    pub my_len: u64,
    pub fopts: FileOptions,
    pub sopts: SessionOptions,
    pub n_peers: u32,
    pub peers: CollectionId,
    pub done: Callback,
    session: Option<Session>,
    received: u64,
    issue_time: Time,
}

impl SliceReader {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        io: CkIo,
        file: crate::pfs::FileId,
        file_size: u64,
        session: (u64, u64),
        slice: (u64, u64),
        fopts: FileOptions,
        sopts: SessionOptions,
        n_peers: u32,
        done: Callback,
    ) -> SliceReader {
        SliceReader {
            io,
            file,
            file_size,
            session_offset: session.0,
            session_bytes: session.1,
            my_offset: slice.0,
            my_len: slice.1,
            fopts,
            sopts,
            n_peers,
            peers: CollectionId(u32::MAX),
            done,
            session: None,
            received: 0,
            issue_time: 0,
        }
    }
}

impl Chare for SliceReader {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                let me = ctx.me();
                let (io, file, size, fopts) =
                    (self.io, self.file, self.file_size, self.fopts.clone());
                io.open(ctx, file, size, fopts, Callback::to_chare(me, EP_OPENED));
            }
            EP_OPENED => {
                let me = ctx.me();
                let (io, file, so, sb, sopts) =
                    (self.io, self.file, self.session_offset, self.session_bytes,
                     self.sopts.clone());
                io.start_read_session(ctx, file, so, sb, sopts, Callback::to_chare(me, EP_READY));
            }
            EP_READY | EP_SESSION_FWD => {
                let s: Session = msg.take();
                if msg.ep == EP_READY {
                    for j in 0..self.n_peers {
                        if ChareRef::new(self.peers, j) != ctx.me() {
                            ctx.send(ChareRef::new(self.peers, j), EP_SESSION_FWD, s);
                        }
                    }
                }
                self.session = Some(s);
                self.issue_time = ctx.now();
                if self.my_len == 0 {
                    let done = self.done.clone();
                    ctx.fire(done, Payload::new(0u64));
                    return;
                }
                let me = ctx.me();
                let (io, off, len) = (self.io, self.my_offset, self.my_len);
                io.read(ctx, &s, off, len, Callback::to_chare(me, EP_DATA));
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                self.received += r.len;
                if self.received == self.my_len {
                    let done = self.done.clone();
                    ctx.fire(done, Payload::new(self.received));
                }
            }
            other => panic!("SliceReader: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// [`SliceReader`]'s declared message protocol (see
/// [`crate::amt::protocol`]). `EP_OPENED` is `Any`: the open callback
/// delivers the library's handle-or-error payload, which is ignored.
pub fn slice_reader_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "SliceReader",
        module: "harness/experiments.rs",
        handles: vec![
            ep_spec!(EP_GO, PayloadKind::Signal),
            ep_spec!(EP_OPENED, PayloadKind::Any),
            ep_spec!(EP_READY, PayloadKind::of::<Session>()),
            ep_spec!(EP_DATA, PayloadKind::of::<ReadResult>()),
            ep_spec!(EP_SESSION_FWD, PayloadKind::of::<Session>()),
        ],
        sends: vec![send_spec!("SliceReader", EP_SESSION_FWD, PayloadKind::of::<Session>())],
    }
}

/// Drive `nclients` CkIO clients reading a whole file; returns
/// (completion time, engine).
pub fn run_ckio_read(
    nodes: u32,
    pes: u32,
    file_size: u64,
    nclients: u32,
    fopts: FileOptions,
    sopts: SessionOptions,
    seed: u64,
) -> (Time, Engine) {
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
        .with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(nclients);
    let per = file_size / nclients as u64;
    let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| {
        let lo = i as u64 * per;
        let hi = if i == nclients - 1 { file_size } else { lo + per };
        SliceReader::new(
            io,
            file,
            file_size,
            (0, file_size),
            (lo, hi - lo),
            fopts.clone(),
            sopts.clone(),
            nclients,
            Callback::Future(fut),
        )
    });
    for i in 0..nclients {
        eng.chare_mut::<SliceReader>(ChareRef::new(cid, i)).peers = cid;
    }
    eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
    eng.run();
    assert!(eng.future_done(fut), "ckio read incomplete");
    let t = eng.take_future(fut).iter().map(|(t, _)| *t).max().unwrap();
    (t, eng)
}

/// Drive `nclients` naive clients reading a whole file; returns
/// (completion time, engine).
pub fn run_naive_read(
    nodes: u32,
    pes: u32,
    file_size: u64,
    nclients: u32,
    block_pe: bool,
    seed: u64,
) -> (Time, Engine) {
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
        .with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let fut = eng.future(nclients);
    let per = file_size / nclients as u64;
    let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| {
        let lo = i as u64 * per;
        let hi = if i == nclients - 1 { file_size } else { lo + per };
        let mut c = NaiveClient::new(file, lo, hi - lo, Callback::Future(fut));
        c.block_pe = block_pe;
        c
    });
    for i in 0..nclients {
        eng.inject_signal(ChareRef::new(cid, i), EP_N_GO);
    }
    eng.run();
    assert!(eng.future_done(fut), "naive read incomplete");
    let t = eng.take_future(fut).iter().map(|(t, _)| *t).max().unwrap();
    (t, eng)
}

// =====================================================================
// Fig. 1 — naive over-decomposed input throughput vs #clients
// =====================================================================

pub fn fig1_naive_clients(reps: u32) -> Table {
    let mut t = Table::new(
        "Fig.1: naive overdecomposed input (16 nodes x 32 PEs; GiB/s, mean/std over reps)",
        &["file", "clients", "gibs_mean", "gibs_std", "time_s"],
    );
    for &size in &[gib(1), gib(4), gib(16)] {
        for exp in [4u32, 6, 8, 9, 10, 11, 12, 13] {
            let clients = 1u32 << exp;
            let samples: Vec<f64> = (0..reps)
                .map(|r| {
                    let (tt, _) = run_naive_read(
                        PAPER_NODES,
                        PAPER_PES,
                        size,
                        clients,
                        false,
                        100 + r as u64,
                    );
                    gibs(size, tt)
                })
                .collect();
            let s = Summary::of(&samples);
            t.row(vec![
                crate::util::human_bytes(size),
                clients.to_string(),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.stddev),
                format!("{:.3}", size as f64 / (1u64 << 30) as f64 / s.mean),
            ]);
        }
    }
    t
}

// =====================================================================
// Fig. 2 — disk read vs network transfer of the same bytes
// =====================================================================

pub fn fig2_disk_vs_net(reps: u32) -> Table {
    struct Sender {
        peer: Option<ChareRef>,
        bytes: u64,
        done: Callback,
    }
    impl Chare for Sender {
        fn receive(&mut self, ctx: &mut Ctx<'_>, msg: Msg) {
            match msg.ep {
                EP_GO => {
                    let peer = self.peer.unwrap();
                    let bytes = self.bytes;
                    ctx.send_sized(
                        peer,
                        EP_DATA,
                        Payload::empty(),
                        bytes,
                        crate::net::Transfer::Eager,
                    );
                }
                EP_DATA => {
                    let done = self.done.clone();
                    ctx.fire(done, Payload::empty());
                }
                other => panic!("unknown ep {other}"),
            }
        }
        impl_chare_any!();
    }

    let mut t = Table::new(
        "Fig.2: time to read from PFS vs send same bytes over the network (2 nodes, 1 task each)",
        &["size", "read_s", "net_s", "ratio"],
    );
    for exp in [6u64, 7, 8, 9, 10, 11, 12] {
        let size = mib(1 << exp);
        // Read time: one client reads the whole file.
        let read_s: f64 = (0..reps)
            .map(|r| {
                let (tt, _) = run_naive_read(2, 1, size, 1, false, 7 + r as u64);
                time::to_secs(tt)
            })
            .sum::<f64>()
            / reps as f64;
        // Network time: send the same bytes node 0 → node 1.
        let mut eng = Engine::new(EngineConfig::sim(2, 1));
        let fut = eng.future(1);
        let b = eng
            .create_singleton(Pe(1), Sender { peer: None, bytes: 0, done: Callback::Future(fut) });
        let a = eng
            .create_singleton(Pe(0), Sender { peer: Some(b), bytes: size, done: Callback::Ignore });
        eng.inject_signal(a, EP_GO);
        eng.run();
        let net_s = time::to_secs(eng.take_future(fut)[0].0);
        t.row(vec![
            crate::util::human_bytes(size),
            format!("{read_s:.4}"),
            format!("{net_s:.4}"),
            format!("{:.1}x", read_s / net_s),
        ]);
    }
    t
}

// =====================================================================
// Fig. 4 — naive vs CkIO as the client count scales
// =====================================================================

pub fn fig4_ckio_vs_naive(reps: u32) -> Table {
    let size = gib(4);
    let mut t = Table::new(
        "Fig.4: naive vs CkIO, 4 GiB file, 16 nodes x 32 PEs (time_s mean/std)",
        &["clients", "naive_s", "naive_std", "ckio_s", "ckio_std", "ckio_readers"],
    );
    let readers = crate::ckio::options::auto_readers(
        size,
        &crate::amt::topology::Topology::new(PAPER_NODES, PAPER_PES),
    );
    for exp in [4u32, 6, 8, 9, 10, 11, 12, 13] {
        let clients = 1u32 << exp;
        let naive: Vec<f64> = (0..reps)
            .map(|r| {
                time::to_secs(
                    run_naive_read(PAPER_NODES, PAPER_PES, size, clients, false, 31 + r as u64).0,
                )
            })
            .collect();
        let ckio: Vec<f64> = (0..reps)
            .map(|r| {
                time::to_secs(
                    run_ckio_read(
                        PAPER_NODES,
                        PAPER_PES,
                        size,
                        clients,
                        FileOptions::with_readers(readers),
                        SessionOptions::default(),
                        91 + r as u64,
                    )
                    .0,
                )
            })
            .collect();
        let (ns, cs) = (Summary::of(&naive), Summary::of(&ckio));
        t.row(vec![
            clients.to_string(),
            format!("{:.3}", ns.mean),
            format!("{:.3}", ns.stddev),
            format!("{:.3}", cs.mean),
            format!("{:.3}", cs.stddev),
            readers.to_string(),
        ]);
    }
    t
}

// =====================================================================
// Fig. 7 — MPI-IO collective vs CkIO across node counts
// =====================================================================

pub fn fig7_mpiio_vs_ckio(reps: u32) -> Table {
    use crate::baselines::collective::{equal_slices, CollectiveConfig, MpiRank, EP_C_GO};
    let size = gib(1);
    let mut t = Table::new(
        "Fig.7: MPI-IO collective vs CkIO, 1 GiB, 32 ranks/node (time_s)",
        &["nodes", "mpiio_s", "ckio32_s", "ckio64_s"],
    );
    for nodes in [1u32, 2, 4, 8] {
        let pes = 32;
        // MPI-IO collective (1 aggregator per node, ROMIO default).
        let mpiio: f64 = (0..reps)
            .map(|rep| {
                let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(17 + rep as u64))
                    .with_sim_pfs(PfsConfig::default());
                let file = eng.core.sim_pfs_mut().create_file(size);
                let nranks = nodes * pes;
                let slices = equal_slices(0, size, nranks);
                let aggregators: Vec<u32> = (0..nodes).map(|n| n * pes).collect();
                let cfg = CollectiveConfig { file, range: (0, size), aggregators, nranks };
                let fut = eng.future(nranks);
                let slices2 = slices.clone();
                let cid = eng.create_array(nranks, &Placement::RoundRobinPes, |r| {
                    MpiRank::new(
                        cfg.clone(),
                        r,
                        &slices2,
                        CollectionId(u32::MAX),
                        Callback::Future(fut),
                    )
                });
                for r in 0..nranks {
                    eng.chare_mut::<MpiRank>(ChareRef::new(cid, r)).ranks = cid;
                }
                for r in 0..nranks {
                    eng.inject_signal(ChareRef::new(cid, r), EP_C_GO);
                }
                eng.run();
                assert!(eng.future_done(fut));
                time::to_secs(eng.take_future(fut).iter().map(|(t, _)| *t).max().unwrap())
            })
            .sum::<f64>()
            / reps as f64;
        // CkIO with 32 and 64 buffer chares per node (1 client per PE).
        let ckio_for = |per_node: u32, seed: u64| -> f64 {
            (0..reps)
                .map(|rep| {
                    time::to_secs(
                        run_ckio_read(
                            nodes,
                            pes,
                            size,
                            nodes * pes,
                            FileOptions::with_readers(per_node * nodes),
                            SessionOptions::default(),
                            seed + rep as u64,
                        )
                        .0,
                    )
                })
                .sum::<f64>()
                / reps as f64
        };
        t.row(vec![
            nodes.to_string(),
            format!("{mpiio:.3}"),
            format!("{:.3}", ckio_for(32, 55)),
            format!("{:.3}", ckio_for(64, 77)),
        ]);
    }
    t
}

// =====================================================================
// Fig. 8 — runtime with/without background work: naive vs CkIO
// =====================================================================

pub fn fig8_overlap_runtime(reps: u32) -> Table {
    let size = gib(1);
    let (nodes, pes) = (4u32, 2u32);
    let npes = nodes * pes;
    let nclients = 8u32;
    // Fixed background work per PE: 40k iterations x 10 µs = 0.4 s.
    let quota = 40_000u64;
    let slice = 10 * MICROS;

    // One run: returns (total_s, bg_s).
    let run_one = |ckio_mode: bool, with_bg: bool, seed: u64| -> (f64, f64) {
        let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
            .with_sim_pfs(PfsConfig::default());
        let file = eng.core.sim_pfs_mut().create_file(size);
        let per = size / nclients as u64;
        let read_fut = eng.future(nclients);
        if ckio_mode {
            let io = CkIo::boot(&mut eng);
            let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| {
                SliceReader::new(
                    io,
                    file,
                    size,
                    (0, size),
                    (i as u64 * per, per),
                    FileOptions::with_readers(8),
                    SessionOptions::default(),
                    nclients,
                    Callback::Future(read_fut),
                )
            });
            for i in 0..nclients {
                eng.chare_mut::<SliceReader>(ChareRef::new(cid, i)).peers = cid;
            }
            eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
        } else {
            let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| {
                let mut c = NaiveClient::new(file, i as u64 * per, per, Callback::Future(read_fut));
                c.block_pe = true; // synchronous read from task code
                c
            });
            for i in 0..nclients {
                eng.inject_signal(ChareRef::new(cid, i), EP_N_GO);
            }
        }
        if with_bg {
            let bg_fut = eng.future(npes);
            let grp =
                eng.create_group(|_| BgWorker::new(slice, Some(quota), Callback::Future(bg_fut)));
            for pe in 0..npes {
                eng.inject_signal(ChareRef::new(grp, pe), EP_BG_START);
            }
        }
        let end = eng.run();
        assert!(eng.future_done(read_fut));
        let bg_s = time::to_secs(eng.core.metrics.duration(keys::BG_WORK));
        (time::to_secs(end), bg_s)
    };

    let mut t = Table::new(
        "Fig.8: total runtime +/- fixed background work (4 nodes x 2 PEs, 8 clients, 8 buffers, 1 GiB)",
        &["scheme", "bg", "total_s", "bg_work_s", "io_only_s"],
    );
    for (label, ckio_mode) in [("naive", false), ("ckio", true)] {
        for with_bg in [false, true] {
            let mut tot = 0.0;
            let mut bg = 0.0;
            for rep in 0..reps {
                let (ts, bs) = run_one(ckio_mode, with_bg, 400 + rep as u64);
                tot += ts;
                bg += bs;
            }
            let (tot, bg) = (tot / reps as f64, bg / reps as f64);
            t.row(vec![
                label.into(),
                if with_bg { "yes" } else { "no" }.into(),
                format!("{tot:.3}"),
                format!("{bg:.3}"),
                format!("{:.3}", tot - bg / npes as f64),
            ]);
        }
    }
    t
}

// =====================================================================
// Fig. 9 — fraction of input time usable for background work
// =====================================================================

/// Collector: stops the bg group when all reads are done.
struct Collector {
    expected: u32,
    got: u32,
    bg_group: CollectionId,
    npes: u32,
    done: Callback,
}
pub const EP_COLLECT: Ep = 21;
impl Chare for Collector {
    fn receive(&mut self, ctx: &mut Ctx<'_>, msg: Msg) {
        match msg.ep {
            EP_COLLECT => {
                self.got += 1;
                if self.got == self.expected {
                    for pe in 0..self.npes {
                        ctx.send_group(self.bg_group, Pe(pe), EP_BG_STOP, ());
                    }
                    let now = ctx.now();
                    let done = self.done.clone();
                    ctx.fire(done, Payload::new(now));
                }
            }
            other => panic!("Collector: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// [`Collector`]'s declared message protocol (see
/// [`crate::amt::protocol`]). Each completion carries the reader's
/// delivered byte count.
pub fn collector_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "Collector",
        module: "harness/experiments.rs",
        handles: vec![ep_spec!(EP_COLLECT, PayloadKind::of::<u64>())],
        sends: vec![send_spec!("BgWorker", EP_BG_STOP, PayloadKind::Signal)],
    }
}

pub fn fig9_overlap_fraction(reps: u32) -> Table {
    let size = gib(1);
    let (nodes, pes) = (4u32, 2u32);
    let npes = nodes * pes;
    let mut t = Table::new(
        "Fig.9: input time vs background-work fraction (4 nodes x 2 PEs, 8 buffers)",
        &["clients", "clients_per_pe", "read_s", "bg_fraction"],
    );
    for exp in [3u32, 5, 7, 9, 10, 11, 12, 13] {
        let clients = 1u32 << exp;
        let mut read_s = 0.0;
        let mut frac = 0.0;
        for rep in 0..reps {
            let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(900 + rep as u64))
                .with_sim_pfs(PfsConfig::default());
            let file = eng.core.sim_pfs_mut().create_file(size);
            let io = CkIo::boot(&mut eng);
            let per = size / clients as u64;
            let bg_fut = eng.future(npes);
            let done_fut = eng.future(1);
            let grp =
                eng.create_group(|_| BgWorker::new(10 * MICROS, None, Callback::Future(bg_fut)));
            let collector = eng.create_singleton(
                Pe(0),
                Collector {
                    expected: clients,
                    got: 0,
                    bg_group: grp,
                    npes,
                    done: Callback::Future(done_fut),
                },
            );
            let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
                SliceReader::new(
                    io,
                    file,
                    size,
                    (0, size),
                    (i as u64 * per, per),
                    FileOptions::with_readers(8),
                    SessionOptions::default(),
                    clients,
                    Callback::to_chare(collector, EP_COLLECT),
                )
            });
            for i in 0..clients {
                eng.chare_mut::<SliceReader>(ChareRef::new(cid, i)).peers = cid;
            }
            eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
            for pe in 0..npes {
                eng.inject_signal(ChareRef::new(grp, pe), EP_BG_START);
            }
            eng.run();
            assert!(eng.future_done(done_fut));
            let read_end = {
                let mut v = eng.take_future(done_fut);
                v.pop().unwrap().1.take::<Time>()
            };
            let bg = eng.core.metrics.duration(keys::BG_WORK);
            read_s += time::to_secs(read_end);
            // Fraction of the PE-seconds during input that ran bg work.
            frac += time::to_secs(bg) / (npes as f64 * time::to_secs(read_end));
        }
        t.row(vec![
            clients.to_string(),
            (clients / npes).to_string(),
            format!("{:.3}", read_s / reps as f64),
            format!("{:.3}", frac / reps as f64),
        ]);
    }
    t
}

// =====================================================================
// Fig. 12 — migration for locality: pre vs post read times
// =====================================================================

pub fn fig12_migration(reps: u32) -> Table {
    let mut t = Table::new(
        "Fig.12: cross-node read pre-migration vs local read post-migration (2 nodes, 1 PE each)",
        &["file", "pre_s", "post_s", "speedup"],
    );
    for exp in [6u32, 7, 8, 9, 10, 11, 12] {
        let size = mib(1 << exp);
        let mut pre = 0.0;
        let mut post = 0.0;
        for rep in 0..reps {
            let (p1, p2) = migration_run(size, 1200 + rep as u64);
            pre += p1;
            post += p2;
        }
        t.row(vec![
            crate::util::human_bytes(size),
            format!("{:.4}", pre / reps as f64),
            format!("{:.4}", post / reps as f64),
            format!("{:.2}x", pre / post),
        ]);
    }
    t
}

/// Public single-size entry for the migration experiment (used by
/// `examples/migration_locality.rs`).
pub fn fig12_migration_single(size: u64, seed: u64) -> (f64, f64) {
    migration_run(size, seed)
}

/// MigClient's post-migration re-read trigger (self-signal).
const EP_MIG_READ2: Ep = 30;

/// MigClient's declared message protocol (see [`crate::amt::protocol`]).
/// The chare type itself is local to [`migration_run`]; only its EP
/// surface is public, via this spec.
pub fn mig_client_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "MigClient",
        module: "harness/experiments.rs",
        handles: vec![
            ep_spec!(EP_GO, PayloadKind::Signal),
            ep_spec!(EP_OPENED, PayloadKind::Any),
            ep_spec!(EP_READY, PayloadKind::of::<Session>()),
            ep_spec!(EP_DATA, PayloadKind::of::<ReadResult>()),
            ep_spec!(EP_SESSION_FWD, PayloadKind::of::<Session>()),
            ep_spec!(EP_MIG_READ2, PayloadKind::Signal),
        ],
        sends: vec![
            send_spec!("MigClient", EP_SESSION_FWD, PayloadKind::of::<Session>()),
            send_spec!("MigClient", EP_MIG_READ2, PayloadKind::Signal),
        ],
    }
}

/// The paper's migration experiment: clients read remote buffers' data,
/// migrate to the data, read again. Returns (pre_s, post_s) — the max of
/// the two clients' read times per phase.
fn migration_run(size: u64, seed: u64) -> (f64, f64) {
    struct MigClient {
        io: CkIo,
        file: crate::pfs::FileId,
        size: u64,
        index: u32,
        peers: CollectionId,
        session: Option<Session>,
        /// (offset, len) this client wants — the *other* node's buffer.
        want: (u64, u64),
        /// 0 = warmup (absorbs the prefetch wait; untimed),
        /// 1 = pre-migration timed read, 2 = post-migration timed read.
        phase: u8,
        read_started: Time,
        report: Callback,
    }
    impl MigClient {
        fn issue(&mut self, ctx: &mut Ctx<'_>) {
            let s = *self.session.as_ref().unwrap();
            self.read_started = ctx.now();
            let me = ctx.me();
            let (io, want) = (self.io, self.want);
            io.read(ctx, &s, want.0, want.1, Callback::to_chare(me, EP_DATA));
        }
    }
    impl Chare for MigClient {
        fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
            match msg.ep {
                EP_GO => {
                    if self.index == 0 {
                        let me = ctx.me();
                        let (io, file, size) = (self.io, self.file, self.size);
                        io.open(
                            ctx,
                            file,
                            size,
                            FileOptions {
                                num_readers: Some(2),
                                placement: ReaderPlacement::Explicit(vec![0, 1]),
                            },
                            Callback::to_chare(me, EP_OPENED),
                        );
                    }
                }
                EP_OPENED => {
                    let me = ctx.me();
                    let (io, file, size) = (self.io, self.file, self.size);
                    io.start_read_session(
                        ctx,
                        file,
                        0,
                        size,
                        SessionOptions::default(),
                        Callback::to_chare(me, EP_READY),
                    );
                }
                EP_READY | EP_SESSION_FWD => {
                    let s: Session = msg.take();
                    if msg.ep == EP_READY {
                        ctx.send(ChareRef::new(self.peers, 1), EP_SESSION_FWD, s);
                    }
                    self.session = Some(s);
                    self.issue(ctx);
                }
                EP_DATA => {
                    let _r: ReadResult = msg.take();
                    let took = ctx.now() - self.read_started;
                    let phase = self.phase;
                    match phase {
                        0 => {
                            // Warmup done: the buffers' prefetch is
                            // resident. Time the real cross-node read.
                            self.phase = 1;
                            self.issue(ctx);
                        }
                        1 => {
                            let report = self.report.clone();
                            ctx.fire(report, Payload::new((self.index, 1u8, took)));
                            self.phase = 2;
                            // Migrate to the other PE — where our data lives.
                            let dest = Pe(1 - self.index);
                            ctx.migrate_me(dest);
                            let me = ctx.me();
                            ctx.signal(me, EP_MIG_READ2);
                        }
                        _ => {
                            let report = self.report.clone();
                            ctx.fire(report, Payload::new((self.index, 2u8, took)));
                        }
                    }
                }
                EP_MIG_READ2 => self.issue(ctx),
                other => panic!("unknown ep {other}"),
            }
        }
        impl_chare_any!();
    }

    let mut eng =
        Engine::new(EngineConfig::sim(2, 1).with_seed(seed)).with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(size);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(4); // 2 clients × 2 phases
    let half = size / 2;
    let cid = eng.create_array(2, &Placement::Explicit(vec![Pe(0), Pe(1)]), |i| MigClient {
        io,
        file,
        size,
        index: i,
        peers: CollectionId(u32::MAX),
        session: None,
        // c0 (on node 0) wants the second half — owned by b1 on node 1;
        // c1 wants the first half — owned by b0 on node 0.
        want: if i == 0 { (half, size - half) } else { (0, half) },
        phase: 0,
        read_started: 0,
        report: Callback::Future(fut),
    });
    for i in 0..2 {
        eng.chare_mut::<MigClient>(ChareRef::new(cid, i)).peers = cid;
    }
    eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
    eng.run();
    assert!(eng.future_done(fut));
    let mut pre: Time = 0;
    let mut post: Time = 0;
    for (_, mut p) in eng.take_future(fut) {
        let (_, phase, took) = p.take::<(u32, u8, Time)>();
        if phase == 1 {
            pre = pre.max(took);
        } else {
            post = post.max(took);
        }
    }
    (time::to_secs(pre), time::to_secs(post))
}

// =====================================================================
// Fig. 13 — mini-ChaNGa input under the three schemes
// =====================================================================

pub fn fig13_changa(reps: u32, n_tp: u32) -> Table {
    // 1 GiB of particle records.
    let nbodies = gib(1) / crate::apps::changa::tipsy::RECORD_BYTES;
    let mut t = Table::new(
        format!(
            "Fig.13: ChaNGa input, 1 GiB Tipsy, {n_tp} TreePieces, 32 PEs/node (time_s; speedup = best hand-opt / best ckio)"
        ),
        &["nodes", "unopt_s", "handopt_s", "ckio_s", "speedup"],
    );
    for nodes in [1u32, 2, 4, 8, 16] {
        let mut means = Vec::new();
        let mut bests = Vec::new();
        for scheme in [Scheme::Unopt, Scheme::HandOpt, Scheme::CkIo] {
            let samples: Vec<f64> = (0..reps)
                .map(|r| {
                    time::to_secs(
                        run_changa_input(nodes, 32, n_tp, nbodies, scheme, 2000 + r as u64)
                            .input_time,
                    )
                })
                .collect();
            means.push(Summary::of(&samples).mean);
            bests.push(samples.iter().cloned().fold(f64::MAX, f64::min));
        }
        t.row(vec![
            nodes.to_string(),
            format!("{:.3}", means[0]),
            format!("{:.3}", means[1]),
            format!("{:.3}", means[2]),
            format!("{:.2}x", bests[1] / bests[2]),
        ]);
    }
    t
}

// =====================================================================
// §V — execution-time breakdown
// =====================================================================

pub fn sec5_breakdown(reps: u32) -> Table {
    // Paper §V methodology: the run is I/O bound (io_s ≈ prefetch
    // completion); *data permutation* is what CkIO adds over the naive
    // run at the same decomposition (§V.B compares 2^9 buffers + 2^9
    // clients against naive 2^9 clients); *over-decomposition overhead*
    // is the per-task dispatch cost (per PE).
    let size = gib(4);
    let mut t = Table::new(
        "SecV: CkIO execution-time breakdown (4 GiB, 16x32 PEs, 2^9 buffers)",
        &["clients", "ckio_s", "naive_s", "io_s", "permute_s", "overdecomp_s", "ckio_vs_naive"],
    );
    for exp in [9u32, 11, 13] {
        let clients = 1u32 << exp;
        let mut total = 0.0;
        let mut naive = 0.0;
        let mut io = 0.0;
        let mut od = 0.0;
        for rep in 0..reps {
            let (tt, eng) = run_ckio_read(
                PAPER_NODES,
                PAPER_PES,
                size,
                clients,
                FileOptions::with_readers(512),
                SessionOptions::default(),
                3000 + rep as u64,
            );
            total += time::to_secs(tt);
            io += eng.core.metrics.value(keys::LAST_IO_NS) / 1e9;
            naive += time::to_secs(
                run_naive_read(PAPER_NODES, PAPER_PES, size, clients, false, 3000 + rep as u64).0,
            );
            // Over-decomposition overhead: per-task dispatch cost summed
            // across the run, averaged over PEs.
            let tasks = eng.core.metrics.counter(keys::TASKS);
            od += time::to_secs(tasks * eng.core.cost.dispatch_overhead)
                / (PAPER_NODES * PAPER_PES) as f64;
        }
        let (total, naive, io, od) =
            (total / reps as f64, naive / reps as f64, io / reps as f64, od / reps as f64);
        let permute = (total - naive).max(0.0);
        t.row(vec![
            clients.to_string(),
            format!("{total:.3}"),
            format!("{naive:.3}"),
            format!("{io:.3}"),
            format!("{permute:.3}"),
            format!("{od:.4}"),
            format!("{:+.0}%", 100.0 * (total - naive) / naive),
        ]);
    }
    t
}

// =====================================================================
// §VI.C ablation — splintered I/O
// =====================================================================

pub fn ablation_splinter(reps: u32) -> Table {
    let size = gib(1);
    let mut t = Table::new(
        "Ablation (SecVI.C): splintered I/O — latency of an early 4 MiB read (1 buffer, 1 GiB span)",
        &["splinter", "first_read_s", "full_prefetch_s"],
    );
    for splinter in [None, Some(mib(256)), Some(mib(64)), Some(mib(16)), Some(mib(4))] {
        let mut first = 0.0;
        let mut full = 0.0;
        for rep in 0..reps {
            let mut eng = Engine::new(EngineConfig::sim(2, 2).with_seed(4000 + rep as u64))
                .with_sim_pfs(PfsConfig::default());
            let file = eng.core.sim_pfs_mut().create_file(size);
            let io = CkIo::boot(&mut eng);
            let fut = eng.future(1);
            let sopts = SessionOptions { splinter_bytes: splinter, ..Default::default() };
            let cid = eng.create_array(1, &Placement::RoundRobinPes, |_| {
                SliceReader::new(
                    io,
                    file,
                    size,
                    (0, size),
                    (0, mib(4)),
                    FileOptions::with_readers(1),
                    sopts.clone(),
                    1,
                    Callback::Future(fut),
                )
            });
            eng.chare_mut::<SliceReader>(ChareRef::new(cid, 0)).peers = cid;
            eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
            let end = eng.run();
            assert!(eng.future_done(fut));
            first += time::to_secs(eng.take_future(fut)[0].0);
            full += time::to_secs(end);
        }
        t.row(vec![
            splinter.map_or("none".into(), crate::util::human_bytes),
            format!("{:.4}", first / reps as f64),
            format!("{:.4}", full / reps as f64),
        ]);
    }
    t
}

// =====================================================================
// svc_concurrent — K concurrent read sessions (PR 1)
// =====================================================================
//
// The production scenario the multi-session refactor enables: K
// independent workloads, each with its own read session (mixed same-file
// and distinct-file), open/read/close concurrently against one shared
// PFS. Reports aggregate delivered throughput and per-read tail latency.

const EP_CC_GO: Ep = 30;
const EP_CC_OPENED: Ep = 31;
const EP_CC_SESSION: Ep = 32;
const EP_CC_DATA: Ep = 33;
const EP_CC_SLICE_DONE: Ep = 34;
const EP_CC_CLOSED: Ep = 35;
const EP_CC_FCLOSED: Ep = 36;

/// One client of one concurrent-session workload. Element 0 of each
/// session's array is the leader: it opens the file, starts the session,
/// broadcasts the handle, and — once every peer's slice arrived — closes
/// the session and then the file (exercising the refcounted open/close
/// and the drain-teardown path on every run).
pub struct ConcurrentClient {
    io: CkIo,
    file: crate::pfs::FileId,
    file_size: u64,
    index: u32,
    n_peers: u32,
    /// Set post-creation by the driver.
    pub peers: CollectionId,
    fopts: FileOptions,
    sopts: SessionOptions,
    my_offset: u64,
    my_len: u64,
    session: Option<Session>,
    go_time: Time,
    read_issued: Time,
    slices_done: u32,
    /// Leader: fired with the session's elapsed `Time` after file close.
    session_done: Callback,
    /// Fired once per client read with its latency (`Time`).
    read_latency: Callback,
    /// Leader, optional (set post-creation like `peers`): fired with the
    /// close ack's [`SessionOutcome`] — the chaos experiments' window
    /// into served/degraded bytes and retry effort per session.
    pub outcome: Option<Callback>,
}

impl ConcurrentClient {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        io: CkIo,
        file: crate::pfs::FileId,
        file_size: u64,
        index: u32,
        n_peers: u32,
        fopts: FileOptions,
        sopts: SessionOptions,
        slice: (u64, u64),
        session_done: Callback,
        read_latency: Callback,
    ) -> ConcurrentClient {
        ConcurrentClient {
            io,
            file,
            file_size,
            index,
            n_peers,
            peers: CollectionId(u32::MAX),
            fopts,
            sopts,
            my_offset: slice.0,
            my_len: slice.1,
            session: None,
            go_time: 0,
            read_issued: 0,
            slices_done: 0,
            session_done,
            read_latency,
            outcome: None,
        }
    }

    fn finish(&mut self, ctx: &mut Ctx<'_>) {
        let elapsed = ctx.now() - self.go_time;
        let done = self.session_done.clone();
        ctx.fire(done, Payload::new(elapsed));
    }
}

impl Chare for ConcurrentClient {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_CC_GO => {
                self.go_time = ctx.now();
                let me = ctx.me();
                let (io, file, size, fopts) =
                    (self.io, self.file, self.file_size, self.fopts.clone());
                io.open(ctx, file, size, fopts, Callback::to_chare(me, EP_CC_OPENED));
            }
            EP_CC_OPENED => {
                let me = ctx.me();
                let (io, file, size, sopts) =
                    (self.io, self.file, self.file_size, self.sopts.clone());
                io.start_read_session(
                    ctx,
                    file,
                    0,
                    size,
                    sopts,
                    Callback::to_chare(me, EP_CC_SESSION),
                );
            }
            EP_CC_SESSION => {
                let s: Session = msg.take();
                if self.index == 0 && self.session.is_none() {
                    for j in 1..self.n_peers {
                        ctx.send(ChareRef::new(self.peers, j), EP_CC_SESSION, s);
                    }
                }
                self.session = Some(s);
                if self.my_len == 0 {
                    ctx.send(ChareRef::new(self.peers, 0), EP_CC_SLICE_DONE, ());
                    return;
                }
                self.read_issued = ctx.now();
                let me = ctx.me();
                let (io, off, len) = (self.io, self.my_offset, self.my_len);
                io.read(ctx, &s, off, len, Callback::to_chare(me, EP_CC_DATA));
            }
            EP_CC_DATA => {
                let r: ReadResult = msg.take();
                debug_assert_eq!(r.len, self.my_len);
                let latency = ctx.now() - self.read_issued;
                let lat_cb = self.read_latency.clone();
                ctx.fire(lat_cb, Payload::new(latency));
                ctx.send(ChareRef::new(self.peers, 0), EP_CC_SLICE_DONE, ());
            }
            EP_CC_SLICE_DONE => {
                self.slices_done += 1;
                if self.slices_done == self.n_peers {
                    let sid = self.session.as_ref().expect("leader has session").id;
                    let me = ctx.me();
                    let io = self.io;
                    io.close_read_session(ctx, sid, Callback::to_chare(me, EP_CC_CLOSED));
                }
            }
            EP_CC_CLOSED => {
                // Session-close acks carry the structured SessionOutcome
                // (PR 8); forward it when a collector asked for it.
                let o: SessionOutcome = msg.take();
                if let Some(cb) = self.outcome.clone() {
                    ctx.fire(cb, Payload::new(o));
                }
                let me = ctx.me();
                let (io, file) = (self.io, self.file);
                io.close(ctx, file, Callback::to_chare(me, EP_CC_FCLOSED));
            }
            EP_CC_FCLOSED => self.finish(ctx),
            other => panic!("ConcurrentClient: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// [`ConcurrentClient`]'s declared message protocol (see
/// [`crate::amt::protocol`]). The open/file-close acknowledgements are
/// `Any`: their payloads come from the library and are ignored here.
/// The session-close ack decodes the structured [`SessionOutcome`].
pub fn concurrent_client_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "ConcurrentClient",
        module: "harness/experiments.rs",
        handles: vec![
            ep_spec!(EP_CC_GO, PayloadKind::Signal),
            ep_spec!(EP_CC_OPENED, PayloadKind::Any),
            ep_spec!(EP_CC_SESSION, PayloadKind::of::<Session>()),
            ep_spec!(EP_CC_DATA, PayloadKind::of::<ReadResult>()),
            ep_spec!(EP_CC_SLICE_DONE, PayloadKind::Signal),
            ep_spec!(EP_CC_CLOSED, PayloadKind::of::<SessionOutcome>()),
            ep_spec!(EP_CC_FCLOSED, PayloadKind::Any),
        ],
        sends: vec![
            send_spec!("ConcurrentClient", EP_CC_SESSION, PayloadKind::of::<Session>()),
            send_spec!("ConcurrentClient", EP_CC_SLICE_DONE, PayloadKind::Signal),
        ],
    }
}

/// Assert the CkIO service holds no per-session residue: no live or
/// half-closed sessions, stuck rebind probes, or stuck placement plans
/// in the director, no in-flight assemblies, no session entries or
/// stuck early reads in any manager, no leaked or stranded governor
/// tickets on any data-plane shard — and, since PR 10, no live write
/// sessions or stuck flush barriers in the director, no unacked
/// producer puts in any write assembler, and no dirty span or
/// in-flight forced writeback on any shard. One shared definition of
/// "teardown left nothing behind" for the harness tests, the
/// integration suite, and the examples.
pub fn assert_service_clean(eng: &Engine, io: &CkIo) {
    let director: &crate::ckio::director::Director = eng.chare(io.director);
    assert_eq!(director.active_sessions(), 0, "leaked sessions in director");
    assert_eq!(director.pending_closes(), 0, "stuck closes in director");
    assert_eq!(director.pending_takes(), 0, "stuck rebind probes in director");
    assert_eq!(director.pending_plans(), 0, "stuck placement plans in director");
    assert_eq!(director.flow_sessions(), 0, "leaked consumer-flow matrices in director");
    assert_eq!(director.active_writes(), 0, "leaked write sessions in director");
    assert_eq!(director.pending_flushes(), 0, "stuck flush barriers in director");
    for pe in 0..eng.core.topo.npes() {
        let asm: &crate::ckio::assembler::ReadAssembler =
            eng.chare(ChareRef::new(io.assemblers, pe));
        assert_eq!(asm.outstanding(), 0, "leaked assemblies on PE {pe}");
        assert_eq!(asm.flow_accounts(), 0, "leaked flow accounts on PE {pe}");
        assert_eq!(asm.first_served_count(), 0, "leaked first-served marks on PE {pe}");
        let mgr: &crate::ckio::manager::Manager = eng.chare(ChareRef::new(io.managers, pe));
        assert_eq!(mgr.session_count(), 0, "leaked session entries on PE {pe}");
        assert_eq!(mgr.early_count(), 0, "stuck early reads on PE {pe}");
        let wasm: &crate::ckio::write::WriteAssembler =
            eng.chare(ChareRef::new(io.wassemblers, pe));
        assert_eq!(wasm.pending_puts(), 0, "unacked producer puts on PE {pe}");
        assert_eq!(wasm.live_sessions(), 0, "leaked write-session routes on PE {pe}");
    }
    for s in 0..io.nshards {
        let shard = io.shard(eng, s);
        assert_eq!(shard.admission().inflight(), 0, "governor tickets leaked on shard {s}");
        assert_eq!(shard.admission().queued(), 0, "governor demand stranded on shard {s}");
        assert_eq!(shard.io_waiting(), 0, "io-wait windows left open on shard {s}");
        assert_eq!(
            shard.span_store().dirty_bytes(),
            0,
            "dirty spans survived quiescence on shard {s}"
        );
        assert_eq!(
            shard.pending_writebacks(),
            0,
            "eviction-forced writebacks still in flight on shard {s}"
        );
    }
    assert_eq!(
        eng.core.loc.buffered_count(),
        0,
        "stranded in-flight envelopes in the location manager"
    );
    if eng.core.trace.is_enabled() {
        assert_eq!(
            eng.core.trace.open_spans(),
            0,
            "unbalanced trace spans: every begin must have an end at quiescence"
        );
    }
}

/// Results of one `run_svc_concurrent` run.
#[derive(Clone, Debug)]
pub struct ConcurrentStats {
    pub k: u32,
    /// Total delivered bytes / makespan.
    pub aggregate_gibs: f64,
    /// Start → last session fully closed.
    pub makespan_s: f64,
    /// Per-session elapsed seconds (open → file close), session order.
    pub per_session_s: Vec<f64>,
    /// p99 over every client read's latency.
    pub read_p99_s: f64,
}

/// Drive `k` concurrent read sessions of `file_size` bytes each, with
/// `clients` client chares per session. Sessions alternate between a
/// fresh file and sharing the previous session's file (mixed same-file /
/// distinct-file, as a multi-tenant service sees). Every session closes
/// itself and its file, so the teardown path runs `k` times per call.
#[allow(clippy::too_many_arguments)]
pub fn run_svc_concurrent(
    nodes: u32,
    pes: u32,
    file_size: u64,
    k: u32,
    clients: u32,
    cfg: ServiceConfig,
    fopts: FileOptions,
    sopts: SessionOptions,
    seed: u64,
) -> (ConcurrentStats, CkIo, Engine) {
    assert!(k > 0 && clients > 0 && file_size >= clients as u64);
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
        .with_sim_pfs(PfsConfig::default());
    let mut files = Vec::with_capacity(k as usize);
    for s in 0..k {
        let file = if s % 2 == 1 {
            *files.last().unwrap() // odd sessions share the previous file
        } else {
            eng.core.sim_pfs_mut().create_file(file_size)
        };
        files.push(file);
    }
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_concurrent: valid ServiceConfig");
    let done_fut = eng.future(k);
    let lat_fut = eng.future(k * clients);
    let per = file_size / clients as u64;
    let mut leaders = Vec::with_capacity(k as usize);
    for s in 0..k {
        let file = files[s as usize];
        let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
            let lo = i as u64 * per;
            let hi = if i == clients - 1 { file_size } else { lo + per };
            ConcurrentClient::new(
                io,
                file,
                file_size,
                i,
                clients,
                fopts.clone(),
                sopts.clone(),
                (lo, hi - lo),
                Callback::Future(done_fut),
                Callback::Future(lat_fut),
            )
        });
        for i in 0..clients {
            eng.chare_mut::<ConcurrentClient>(ChareRef::new(cid, i)).peers = cid;
        }
        leaders.push(ChareRef::new(cid, 0));
    }
    for leader in leaders {
        eng.inject_signal(leader, EP_CC_GO);
    }
    eng.run();
    assert!(eng.future_done(done_fut), "svc_concurrent: not all sessions closed");
    assert!(eng.future_done(lat_fut), "svc_concurrent: not all reads completed");

    let done = eng.take_future(done_fut);
    let makespan = done.iter().map(|(t, _)| *t).max().unwrap();
    let per_session_s: Vec<f64> = done
        .into_iter()
        .map(|(_, mut p)| time::to_secs(p.take::<Time>()))
        .collect();
    let mut lats = crate::metrics::Histogram::new();
    for (_, mut p) in eng.take_future(lat_fut) {
        lats.record(p.take::<Time>());
    }
    let read_p99_s = time::to_secs(lats.quantile(0.99));
    let makespan_s = time::to_secs(makespan);
    let stats = ConcurrentStats {
        k,
        aggregate_gibs: gibs(k as u64 * file_size, makespan),
        makespan_s,
        per_session_s,
        read_p99_s,
    };
    (stats, io, eng)
}

/// The `svc_concurrent` experiment family table: K × reader-count sweep
/// at paper scale.
pub fn svc_concurrent(reps: u32) -> Table {
    let size = gib(1);
    let clients = 128u32;
    let mut t = Table::new(
        "svc_concurrent: K concurrent sessions, mixed same/distinct files \
         (16 nodes x 32 PEs, 1 GiB x 128 clients per session; aggregate GiB/s, p99 read latency)",
        &["k", "readers", "agg_gibs", "sess_mean_s", "read_p99_s"],
    );
    for &readers in &[16u32, 64] {
        for &k in &[1u32, 2, 4, 8] {
            let mut agg = 0.0;
            let mut sess = 0.0;
            let mut p99 = 0.0;
            for r in 0..reps {
                let (st, _, _) = run_svc_concurrent(
                    PAPER_NODES,
                    PAPER_PES,
                    size,
                    k,
                    clients,
                    ServiceConfig::default(),
                    FileOptions::with_readers(readers),
                    SessionOptions::default(),
                    7000 + r as u64,
                );
                agg += st.aggregate_gibs;
                sess += st.per_session_s.iter().sum::<f64>() / k as f64;
                p99 += st.read_p99_s;
            }
            let n = reps as f64;
            t.row(vec![
                k.to_string(),
                readers.to_string(),
                format!("{:.2}", agg / n),
                format!("{:.3}", sess / n),
                format!("{:.4}", p99 / n),
            ]);
        }
    }
    t
}

// =====================================================================
// svc_shared — K same-file sessions through the resident-data plane
// =====================================================================
//
// PR 2's acceptance scenario: K concurrent sessions over ONE file. With
// the span store's claim matching, sessions 2..K peer-fetch their bytes
// from session 1's buffer chares (waiting on in-flight greedy reads
// instead of duplicating them), so the file crosses the PFS wire
// approximately once regardless of K — against K× before.

/// Results of one `run_svc_shared` run.
#[derive(Clone, Debug)]
pub struct SharedStats {
    pub k: u32,
    /// Bytes actually read from the PFS (the dedup denominator).
    pub pfs_bytes_read: u64,
    /// Span-store bytes served from resident data instead of the PFS.
    pub store_hit_bytes: u64,
    /// Bytes for which PFS reads were issued.
    pub store_miss_bytes: u64,
    /// Resident bytes LRU-evicted or purged from parked arrays.
    pub store_evicted_bytes: u64,
    /// Reads deferred by the admission governor.
    pub governor_throttled: u64,
    /// Total delivered bytes / makespan.
    pub aggregate_gibs: f64,
    pub makespan_s: f64,
}

/// Drive `k` concurrent read sessions *all over one file* of
/// `file_size` bytes, `clients` client chares per session. Every session
/// closes itself and drops its file ref, so the whole lifecycle runs.
#[allow(clippy::too_many_arguments)]
pub fn run_svc_shared(
    nodes: u32,
    pes: u32,
    file_size: u64,
    k: u32,
    clients: u32,
    cfg: ServiceConfig,
    fopts: FileOptions,
    sopts: SessionOptions,
    seed: u64,
) -> (SharedStats, CkIo, Engine) {
    assert!(k > 0 && clients > 0 && file_size >= clients as u64);
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
        .with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_shared: valid ServiceConfig");
    let done_fut = eng.future(k);
    let lat_fut = eng.future(k * clients);
    let per = file_size / clients as u64;
    let mut leaders = Vec::with_capacity(k as usize);
    for _ in 0..k {
        let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
            let lo = i as u64 * per;
            let hi = if i == clients - 1 { file_size } else { lo + per };
            ConcurrentClient::new(
                io,
                file,
                file_size,
                i,
                clients,
                fopts.clone(),
                sopts.clone(),
                (lo, hi - lo),
                Callback::Future(done_fut),
                Callback::Future(lat_fut),
            )
        });
        for i in 0..clients {
            eng.chare_mut::<ConcurrentClient>(ChareRef::new(cid, i)).peers = cid;
        }
        leaders.push(ChareRef::new(cid, 0));
    }
    for leader in leaders {
        eng.inject_signal(leader, EP_CC_GO);
    }
    eng.run();
    assert!(eng.future_done(done_fut), "svc_shared: not all sessions closed");
    assert!(eng.future_done(lat_fut), "svc_shared: not all reads completed");

    let makespan = eng.take_future(done_fut).iter().map(|(t, _)| *t).max().unwrap();
    let m = &eng.core.metrics;
    let stats = SharedStats {
        k,
        pfs_bytes_read: m.counter(keys::PFS_BYTES),
        store_hit_bytes: m.counter(keys::STORE_HIT),
        store_miss_bytes: m.counter(keys::STORE_MISS),
        store_evicted_bytes: m.counter(keys::STORE_EVICTED),
        governor_throttled: m.counter(keys::GOV_THROTTLED),
        aggregate_gibs: gibs(k as u64 * file_size, makespan),
        makespan_s: time::to_secs(makespan),
    };
    (stats, io, eng)
}

/// The `svc_shared` experiment table: PFS traffic and aggregate delivered
/// throughput as K same-file sessions grow.
pub fn svc_shared(reps: u32) -> Table {
    let size = gib(1);
    let clients = 64u32;
    let readers = 16u32;
    let mut t = Table::new(
        "svc_shared: K concurrent sessions over ONE file \
         (16 nodes x 32 PEs, 1 GiB x 64 clients per session; \
         pfs_ratio = PFS bytes vs K=1 — ~1.0 means the file crossed the wire once)",
        &["k", "pfs_gib", "pfs_ratio", "hit_gib", "agg_gibs", "makespan_s"],
    );
    let mut base_bytes = 0.0f64;
    for &k in &[1u32, 2, 4, 8] {
        let mut pfs = 0.0;
        let mut hit = 0.0;
        let mut agg = 0.0;
        let mut mk = 0.0;
        for r in 0..reps {
            let (st, _, _) = run_svc_shared(
                PAPER_NODES,
                PAPER_PES,
                size,
                k,
                clients,
                ServiceConfig::default(),
                FileOptions::with_readers(readers),
                SessionOptions::default(),
                7600 + r as u64,
            );
            pfs += st.pfs_bytes_read as f64;
            hit += st.store_hit_bytes as f64;
            agg += st.aggregate_gibs;
            mk += st.makespan_s;
        }
        let n = reps as f64;
        if k == 1 {
            base_bytes = pfs / n;
        }
        t.row(vec![
            k.to_string(),
            format!("{:.2}", pfs / n / (1u64 << 30) as f64),
            format!("{:.2}", (pfs / n) / base_bytes),
            format!("{:.2}", hit / n / (1u64 << 30) as f64),
            format!("{:.2}", agg / n),
            format!("{:.3}", mk / n),
        ]);
    }
    t
}

// =====================================================================
// svc_churn — K distinct-file sessions vs the data-plane shard count
// =====================================================================
//
// PR 3's acceptance scenario: K sessions over K *distinct* files (no
// dedup possible) on a deliberately control-plane-heavy PFS shape. With
// one data-plane shard, every claim registration and every admission
// ticket of every session serializes through one chare on one PE — the
// PR 2 director bottleneck, reproduced. Sweeping the shard count spreads
// that coordination across PEs while the I/O work stays bit-for-bit
// identical, so end-to-end time drops monotonically until every file has
// its own shard.

/// Results of one `run_svc_churn` run.
#[derive(Clone, Debug)]
pub struct ChurnStats {
    /// Active shard count (after clamping to the PE count).
    pub shards: u32,
    pub k: u32,
    /// Start → last session fully closed.
    pub makespan_s: f64,
    /// Most data-plane messages processed by any one active shard.
    pub shard_msgs_max: u64,
    /// Mean data-plane messages per active shard.
    pub shard_msgs_mean: f64,
}

/// Drive `k` concurrent sessions over `k` *distinct* files of
/// `file_size` bytes each (`clients` client chares per session), with
/// the data plane hashed over `shards` shards. Every session closes
/// itself and its file, so the full lifecycle churns `k` times.
///
/// The PFS is configured quiet and cheap (no noise, no seek penalty,
/// tiny 2 µs RPC overhead, fast OSTs) and sessions are governed with a
/// cap far above demand: every splinter read still runs the shard
/// ticket protocol — the hot path under test — but admission never
/// reorders I/O, so runs across shard counts differ **only** in where
/// the coordination executes.
pub fn run_svc_churn(
    nodes: u32,
    pes: u32,
    file_size: u64,
    k: u32,
    clients: u32,
    shards: u32,
    seed: u64,
) -> (ChurnStats, CkIo, Engine) {
    assert!(k > 0 && clients > 0 && file_size >= clients as u64);
    let pfs = PfsConfig {
        noise_sigma: 0.0,
        rpc_overhead: time::from_micros(2.0),
        seek_penalty: 0,
        ost_bw: 6.0e9,
        client_window: 8,
        ..PfsConfig::default()
    };
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed)).with_sim_pfs(pfs);
    let files: Vec<crate::pfs::FileId> =
        (0..k).map(|_| eng.core.sim_pfs_mut().create_file(file_size)).collect();
    // Service scope at boot (PR 5): the shard count and the
    // far-above-demand cap are service configuration, not smuggled
    // through a file's open.
    let cfg = ServiceConfig {
        max_inflight_reads: Some(1 << 16),
        data_plane_shards: Some(shards.max(1)),
        ..Default::default()
    };
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_churn: valid ServiceConfig");
    let fopts = FileOptions::with_readers(2);
    // Many tiny splinters: lots of claim/ticket traffic per byte.
    let sopts =
        SessionOptions { splinter_bytes: Some(4 << 10), read_window: 8, ..Default::default() };
    let done_fut = eng.future(k);
    let lat_fut = eng.future(k * clients);
    let per = file_size / clients as u64;
    let mut leaders = Vec::with_capacity(k as usize);
    for s in 0..k {
        let file = files[s as usize];
        let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
            let lo = i as u64 * per;
            let hi = if i == clients - 1 { file_size } else { lo + per };
            ConcurrentClient::new(
                io,
                file,
                file_size,
                i,
                clients,
                fopts.clone(),
                sopts.clone(),
                (lo, hi - lo),
                Callback::Future(done_fut),
                Callback::Future(lat_fut),
            )
        });
        for i in 0..clients {
            eng.chare_mut::<ConcurrentClient>(ChareRef::new(cid, i)).peers = cid;
        }
        leaders.push(ChareRef::new(cid, 0));
    }
    for leader in leaders {
        eng.inject_signal(leader, EP_CC_GO);
    }
    eng.run();
    assert!(eng.future_done(done_fut), "svc_churn: not all sessions closed");
    assert!(eng.future_done(lat_fut), "svc_churn: not all reads completed");

    let makespan = eng.take_future(done_fut).iter().map(|(t, _)| *t).max().unwrap();
    let active =
        eng.chare::<crate::ckio::director::Director>(io.director).active_shards();
    let msgs = io.shard_msgs(&eng);
    let active_msgs = &msgs[..active as usize];
    let shard_msgs_max = *active_msgs.iter().max().unwrap();
    let shard_msgs_mean = active_msgs.iter().sum::<u64>() as f64 / active as f64;
    debug_assert!(
        msgs[active as usize..].iter().all(|&m| m == 0),
        "inactive shards must see no traffic"
    );
    eng.core.metrics.set(keys::SHARD_MSGS_MAX, shard_msgs_max as f64);
    eng.core.metrics.set(keys::SHARD_MSGS_MEAN, shard_msgs_mean);
    let stats = ChurnStats {
        shards: active,
        k,
        makespan_s: time::to_secs(makespan),
        shard_msgs_max,
        shard_msgs_mean,
    };
    (stats, io, eng)
}

/// One row of the canonical churn shard sweep (rep-averaged).
#[derive(Clone, Debug)]
pub struct ChurnSweepRow {
    /// Active shard count (post-clamp).
    pub shards: u32,
    pub k: u32,
    pub makespan_s: f64,
    pub shard_msgs_max: f64,
    pub shard_msgs_mean: f64,
}

/// The canonical churn shard sweep — ONE definition of the shape
/// (cluster, file size, K, clients, shard list, seeds), shared by the
/// `svc_churn` figure table and the `BENCH_pr8.json` `churn` section so
/// the two can never silently report different experiments.
pub fn churn_sweep(reps: u32) -> Vec<ChurnSweepRow> {
    let (nodes, pes) = (4u32, 8);
    let (size, k, clients) = (512u64 << 10, 8u32, 4u32);
    let n = reps.max(1) as f64;
    [1u32, 2, 4, 8, 16]
        .iter()
        .map(|&shards| {
            let mut mk = 0.0;
            let mut mx = 0.0;
            let mut mean = 0.0;
            let mut active = 0u32;
            for r in 0..reps.max(1) {
                let (st, _, _) =
                    run_svc_churn(nodes, pes, size, k, clients, shards, 8500 + r as u64);
                mk += st.makespan_s;
                mx += st.shard_msgs_max as f64;
                mean += st.shard_msgs_mean;
                active = st.shards;
            }
            ChurnSweepRow {
                shards: active,
                k,
                makespan_s: mk / n,
                shard_msgs_max: mx / n,
                shard_msgs_mean: mean / n,
            }
        })
        .collect()
}

/// The `svc_churn` experiment table: end-to-end time and per-shard
/// message counts as the data-plane shard count sweeps 1 → 16.
pub fn svc_churn(reps: u32) -> Table {
    let mut t = Table::new(
        "svc_churn: K=8 sessions over 8 DISTINCT files vs data-plane shard count \
         (4 nodes x 8 PEs, 512 KiB x 4 clients per session, 4 KiB splinters, governed; \
         makespan should drop monotonically to shards=8)",
        &["shards", "k", "makespan_ms", "shard_msgs_max", "shard_msgs_mean", "imbalance"],
    );
    for row in churn_sweep(reps) {
        t.row(vec![
            row.shards.to_string(),
            row.k.to_string(),
            format!("{:.3}", row.makespan_s * 1e3),
            format!("{:.0}", row.shard_msgs_max),
            format!("{:.1}", row.shard_msgs_mean),
            format!("{:.2}x", row.shard_msgs_max / row.shard_msgs_mean.max(1.0)),
        ]);
    }
    t
}

// =====================================================================
// svc_locality — store-aware reader placement vs spread placement
// =====================================================================
//
// PR 4's acceptance scenario: K successive sessions over ONE file whose
// ranges overlap the first session's claims at *shifted* offsets, so a
// later session's buffer index no longer lines up with its data's
// owner. Under the default SpreadNodes placement the peer fetches that
// dedup the prefetch (PR 2) mostly cross PEs; under
// `ReaderPlacement::StoreAware` the director plans each start against
// the span store and creates every overlapping buffer *on the PE of its
// dominant peer source* — the same bytes move, but
// `ckio.place.cross_pe_fetch` collapses toward zero (Fig. 12's locality
// win applied at creation time instead of by migration).

/// Results of one `run_svc_locality` run.
#[derive(Clone, Debug)]
pub struct LocalityStats {
    pub k: u32,
    /// Buffer chares placed by a shard `PlacementPlan`.
    pub planned: u64,
    /// Buffers whose registration found less coverage than planned.
    pub degraded: u64,
    /// Peer-fetched bytes served without crossing a PE.
    pub same_pe_fetch_bytes: u64,
    /// Peer-fetched bytes that crossed PEs.
    pub cross_pe_fetch_bytes: u64,
    /// Total bytes served out of the resident plane (= same + cross
    /// here: no rebinds in this workload).
    pub store_hit_bytes: u64,
    pub makespan_s: f64,
}

/// Drive `k` successive sessions over ONE file of `file_size` bytes with
/// `readers` buffer chares each, all kept open until the end (so every
/// session's claims stay live). Session 0 covers the whole file;
/// sessions 1..k cover half-file windows shifted by one buffer span per
/// session — each later buffer is fully contained in exactly one
/// session-0 claim, but at a *different* array index, which is what
/// makes index-based placement lose locality and store-aware placement
/// win it. Every session's full range is read back (verified against
/// the deterministic file pattern) before the next session starts.
pub fn run_svc_locality(
    nodes: u32,
    pes: u32,
    file_size: u64,
    k: u32,
    readers: u32,
    placement: ReaderPlacement,
    seed: u64,
) -> (LocalityStats, CkIo, Engine) {
    assert!(k >= 1 && readers >= 2);
    assert!(k <= readers + 1, "window shifts beyond the file for k > readers + 1");
    assert_eq!(
        file_size % (2 * readers as u64),
        0,
        "file size must be divisible by 2 x readers for aligned windows"
    );
    let span = file_size / (2 * readers as u64); // later sessions' buffer span
    let splinter = (span / 4).max(1);
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed)).with_sim_pfs(
        PfsConfig { materialize: true, noise_sigma: 0.0, ..PfsConfig::default() },
    );
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot(&mut eng);

    let fopts = FileOptions { num_readers: Some(readers), placement };
    let sopts = SessionOptions { splinter_bytes: Some(splinter), ..Default::default() };
    let open_fut = eng.future(1);
    io.open_driver(&mut eng, file, file_size, fopts, Callback::Future(open_fut));
    eng.run();
    assert!(eng.future_done(open_fut), "svc_locality: open never completed");

    let mut sessions = Vec::with_capacity(k as usize);
    for i in 0..k {
        let (offset, bytes) =
            if i == 0 { (0, file_size) } else { (i as u64 * span, file_size / 2) };
        let ready = eng.future(1);
        io.start_session_driver(
            &mut eng,
            file,
            offset,
            bytes,
            sopts.clone(),
            Callback::Future(ready),
        );
        eng.run();
        assert!(eng.future_done(ready), "svc_locality: session {i} never became ready");
        let (_, mut p) = eng.take_future(ready).pop().unwrap();
        let s = p.take::<Session>();
        // Read the whole session range back through PE 0's manager
        // (the public read_driver, PR 5) and verify it against the file
        // pattern — whatever mix of local copies, cross-PE peer
        // fetches, and PFS reads served it.
        let read_fut = eng.future(1);
        io.read_driver(&mut eng, 0, &s, offset, bytes, Callback::Future(read_fut));
        eng.run();
        assert!(eng.future_done(read_fut), "svc_locality: session {i} read never completed");
        let (_, mut p) = eng.take_future(read_fut).pop().unwrap();
        let r = p.take::<ReadResult>();
        assert_eq!(r.len, bytes);
        let data = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
        assert_eq!(
            crate::pfs::pattern::verify(file, offset, data),
            None,
            "svc_locality: corrupt read in session {i}"
        );
        sessions.push(s);
    }
    for s in sessions {
        let closed = eng.future(1);
        io.close_session_driver(&mut eng, s.id, Callback::Future(closed));
        eng.run();
        assert!(eng.future_done(closed), "svc_locality: close never completed");
    }
    let fclosed = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(fclosed));
    eng.run();
    assert!(eng.future_done(fclosed), "svc_locality: file close never completed");

    let m = &eng.core.metrics;
    let stats = LocalityStats {
        k,
        planned: m.counter(keys::PLACE_PLANNED),
        degraded: m.counter(keys::PLACE_DEGRADED),
        same_pe_fetch_bytes: m.counter(keys::PLACE_SAME_PE),
        cross_pe_fetch_bytes: m.counter(keys::PLACE_CROSS_PE),
        store_hit_bytes: m.counter(keys::STORE_HIT),
        makespan_s: time::to_secs(eng.core.now()),
    };
    (stats, io, eng)
}

/// The canonical StoreAware placement (spread fallback) used by the
/// locality experiment and its example.
pub fn store_aware_spread() -> ReaderPlacement {
    ReaderPlacement::StoreAware { fallback: Box::new(ReaderPlacement::SpreadNodes) }
}

/// The `svc_locality` experiment table: cross-PE peer-fetch bytes under
/// StoreAware vs SpreadNodes placement as K same-file sessions grow.
pub fn svc_locality(reps: u32) -> Table {
    let (nodes, pes) = (2u32, 4u32);
    let (size, readers) = (mib(4), 8u32);
    let n = reps.max(1) as f64;
    let mut t = Table::new(
        "svc_locality: K successive overlapping sessions over ONE file, StoreAware vs \
         SpreadNodes placement (2 nodes x 4 PEs, 4 MiB, 8 readers; cross-PE peer-fetch \
         bytes collapse under StoreAware)",
        &["placement", "k", "same_pe_mib", "cross_pe_mib", "cross_share", "planned", "degraded"],
    );
    for &k in &[2u32, 4, 8] {
        for (label, placement) in
            [("store_aware", store_aware_spread()), ("spread", ReaderPlacement::SpreadNodes)]
        {
            let mut same = 0.0;
            let mut cross = 0.0;
            let mut planned = 0.0;
            let mut degraded = 0.0;
            for r in 0..reps.max(1) {
                let (st, _, _) = run_svc_locality(
                    nodes,
                    pes,
                    size,
                    k,
                    readers,
                    placement.clone(),
                    8700 + r as u64,
                );
                same += st.same_pe_fetch_bytes as f64;
                cross += st.cross_pe_fetch_bytes as f64;
                planned += st.planned as f64;
                degraded += st.degraded as f64;
            }
            let total = (same + cross).max(1.0);
            t.row(vec![
                label.into(),
                k.to_string(),
                format!("{:.2}", same / n / (1u64 << 20) as f64),
                format!("{:.2}", cross / n / (1u64 << 20) as f64),
                format!("{:.3}", cross / total),
                format!("{:.0}", planned / n),
                format!("{:.0}", degraded / n),
            ]);
        }
    }
    t
}

// =====================================================================
// svc_qos — QoS classes under a contended admission cap (PR 5)
// =====================================================================
//
// PR 5's acceptance scenario: Interactive and Bulk sessions contend on
// ONE governed data-plane shard under a tight admission cap. Classless
// (every session Bulk), the FIFO governor drains everyone at the same
// rate and latency-sensitive work waits behind bulk prefetch. With
// classes, the weighted-deficit-round-robin governor dequeues
// Interactive tickets at 4x the Bulk rate (weights 8 : 2), so
// Interactive session makespan p50 drops — while Bulk still completes
// (WDRR is starvation-free) and the governor holds no residue at
// quiescence.

/// Results of one `run_svc_qos` run.
#[derive(Clone, Debug)]
pub struct QosStats {
    /// The static per-shard admission cap the run contended on.
    pub cap: u32,
    /// Per-session elapsed seconds (open → file close), Interactive
    /// sessions.
    pub interactive_s: Vec<f64>,
    /// Per-session elapsed seconds, Bulk sessions.
    pub bulk_s: Vec<f64>,
    pub interactive_p50_s: f64,
    pub bulk_p50_s: f64,
    /// Worst Bulk session (the starvation check: must be finite and the
    /// run must quiesce).
    pub bulk_max_s: f64,
    pub makespan_s: f64,
    /// `ckio.governor.class_granted.*` counters at quiescence.
    pub granted_interactive: u64,
    pub granted_bulk: u64,
    pub granted_scavenger: u64,
    pub throttled: u64,
    /// Governor residue at quiescence (acceptance: both must be 0).
    pub governor_inflight: u32,
    pub governor_queued: usize,
}

/// Drive `n_interactive` Interactive-class and `n_bulk` Bulk-class
/// sessions, each over its *own* file of `file_size` bytes (`clients`
/// client chares per session), all contending on ONE governed
/// data-plane shard under a static admission `cap`. With `classed`
/// false, every session runs as Bulk — the classless baseline the QoS
/// claim is measured against (identical work, identical arrival
/// interleaving; only the class labels differ). With `adaptive` true the
/// static `cap` is replaced by AIMD feedback admission
/// ([`ServiceConfig::adaptive_admission`]) — the mode that exercises
/// annotated `governor/cap` trace events under class contention.
///
/// The PFS is configured quiet (no noise) so the classed/classless
/// comparison is deterministic, and sessions splinter finely so the
/// governor queue — not the disks' raw bandwidth — is the contended
/// resource.
#[allow(clippy::too_many_arguments)]
pub fn run_svc_qos(
    nodes: u32,
    pes: u32,
    file_size: u64,
    n_interactive: u32,
    n_bulk: u32,
    clients: u32,
    cap: u32,
    classed: bool,
    adaptive: bool,
    seed: u64,
) -> (QosStats, CkIo, Engine) {
    assert!(n_interactive > 0 && n_bulk > 0 && clients > 0 && (adaptive || cap > 0));
    assert!(file_size >= clients as u64);
    let pfs = PfsConfig {
        noise_sigma: 0.0,
        rpc_overhead: time::from_micros(2.0),
        seek_penalty: 0,
        ..PfsConfig::default()
    };
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed)).with_sim_pfs(pfs);
    let k = n_interactive + n_bulk;
    let files: Vec<crate::pfs::FileId> =
        (0..k).map(|_| eng.core.sim_pfs_mut().create_file(file_size)).collect();
    let cfg = ServiceConfig {
        max_inflight_reads: if adaptive { None } else { Some(cap) },
        adaptive_admission: adaptive,
        // One shard: every session's tickets meet in one governor —
        // the contention the classes arbitrate.
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_qos: valid ServiceConfig");
    let fopts = FileOptions::with_readers(2);
    let sopts_for = |interactive: bool| SessionOptions {
        class: if classed && interactive { QosClass::Interactive } else { QosClass::Bulk },
        // Fine splinters + a deep window: sustained ticket demand, so
        // the governor queue stays saturated while sessions run.
        splinter_bytes: Some(16 << 10),
        read_window: 8,
        ..Default::default()
    };
    let done_int = eng.future(n_interactive);
    let done_bulk = eng.future(n_bulk);
    let lat_fut = eng.future(k * clients);
    let per = file_size / clients as u64;
    let mut leaders = Vec::with_capacity(k as usize);
    for s in 0..k {
        // Interleave the classes in arrival order (I, B, I, B, …, then
        // whatever class remains): the classless baseline then treats
        // both groups identically, so any p50 gap is the scheduler's
        // doing, not arrival bias.
        let interactive =
            if s % 2 == 0 { s / 2 < n_interactive } else { s / 2 >= n_bulk };
        let file = files[s as usize];
        let done = if interactive { done_int } else { done_bulk };
        let sopts = sopts_for(interactive);
        let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
            let lo = i as u64 * per;
            let hi = if i == clients - 1 { file_size } else { lo + per };
            ConcurrentClient::new(
                io,
                file,
                file_size,
                i,
                clients,
                fopts.clone(),
                sopts.clone(),
                (lo, hi - lo),
                Callback::Future(done),
                Callback::Future(lat_fut),
            )
        });
        for i in 0..clients {
            eng.chare_mut::<ConcurrentClient>(ChareRef::new(cid, i)).peers = cid;
        }
        leaders.push(ChareRef::new(cid, 0));
    }
    for leader in leaders {
        eng.inject_signal(leader, EP_CC_GO);
    }
    eng.run();
    assert!(eng.future_done(done_int), "svc_qos: not all interactive sessions closed");
    assert!(eng.future_done(done_bulk), "svc_qos: not all bulk sessions closed");
    assert!(eng.future_done(lat_fut), "svc_qos: not all reads completed");

    let collect = |fut_vals: Vec<(Time, Payload)>| -> (Vec<f64>, crate::metrics::Histogram, Time) {
        let end = fut_vals.iter().map(|(t, _)| *t).max().unwrap_or(0);
        let mut h = crate::metrics::Histogram::new();
        let mut v: Vec<f64> = fut_vals
            .into_iter()
            .map(|(_, mut p)| {
                let t = p.take::<Time>();
                h.record(t);
                time::to_secs(t)
            })
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (v, h, end)
    };
    let (interactive_s, hist_i, end_i) = collect(eng.take_future(done_int));
    let (bulk_s, hist_b, end_b) = collect(eng.take_future(done_bulk));
    let m = &eng.core.metrics;
    let stats = QosStats {
        cap,
        interactive_p50_s: time::to_secs(hist_i.quantile(0.5)),
        bulk_p50_s: time::to_secs(hist_b.quantile(0.5)),
        bulk_max_s: bulk_s.iter().cloned().fold(0.0, f64::max),
        interactive_s,
        bulk_s,
        makespan_s: time::to_secs(end_i.max(end_b)),
        granted_interactive: m.counter(keys::GOV_GRANTED_INTERACTIVE),
        granted_bulk: m.counter(keys::GOV_GRANTED_BULK),
        granted_scavenger: m.counter(keys::GOV_GRANTED_SCAVENGER),
        throttled: m.counter(keys::GOV_THROTTLED),
        governor_inflight: io.governor_inflight(&eng),
        governor_queued: io.governor_queued(&eng),
    };
    (stats, io, eng)
}

/// The canonical svc_qos shape — shared by the figure table, the
/// `BENCH_pr8.json` `qos` section, and the acceptance test, so they can
/// never silently measure different experiments:
/// (nodes, pes, file_size, n_interactive, n_bulk, clients, cap).
pub const QOS_SHAPE: (u32, u32, u64, u32, u32, u32, u32) = (2, 4, 512 << 10, 3, 3, 4, 2);

/// One classed-vs-classless pair at the canonical shape.
pub fn qos_pair(seed: u64) -> (QosStats, QosStats) {
    let (n, p, size, ni, nb, c, cap) = QOS_SHAPE;
    let (classed, io_a, eng_a) = run_svc_qos(n, p, size, ni, nb, c, cap, true, false, seed);
    let (classless, io_b, eng_b) = run_svc_qos(n, p, size, ni, nb, c, cap, false, false, seed);
    assert_service_clean(&eng_a, &io_a);
    assert_service_clean(&eng_b, &io_b);
    (classed, classless)
}

/// The `svc_qos` experiment table: Interactive vs Bulk session makespan
/// under a contended cap, classed vs classless.
pub fn svc_qos(reps: u32) -> Table {
    let (n, p, size, ni, nb, c, cap) = QOS_SHAPE;
    let mut t = Table::new(
        format!(
            "svc_qos: {ni} Interactive + {nb} Bulk sessions over distinct {} files, one \
             governed shard, cap {cap} ({n} nodes x {p} PEs, {c} clients/session; weighted \
             governor vs classless FIFO baseline)",
            crate::util::human_bytes(size),
        ),
        &[
            "mode",
            "int_p50_ms",
            "bulk_p50_ms",
            "bulk_max_ms",
            "granted_int",
            "granted_bulk",
            "throttled",
        ],
    );
    // Third mode (PR 7): classed admission under AIMD feedback instead
    // of the static cap — the run whose trace carries annotated
    // `governor/cap` adaptation events (`ckio trace svc_qos`).
    for (mode, classed, adaptive) in
        [("classed", true, false), ("classless", false, false), ("classed-adaptive", true, true)]
    {
        let mut ip50 = 0.0;
        let mut bp50 = 0.0;
        let mut bmax = 0.0;
        let mut gi = 0.0;
        let mut gb = 0.0;
        let mut th = 0.0;
        for r in 0..reps.max(1) {
            let (st, io, eng) =
                run_svc_qos(n, p, size, ni, nb, c, cap, classed, adaptive, 9100 + r as u64);
            assert_service_clean(&eng, &io);
            ip50 += st.interactive_p50_s;
            bp50 += st.bulk_p50_s;
            bmax += st.bulk_max_s;
            gi += st.granted_interactive as f64;
            gb += st.granted_bulk as f64;
            th += st.throttled as f64;
        }
        let nr = reps.max(1) as f64;
        t.row(vec![
            mode.into(),
            format!("{:.3}", ip50 / nr * 1e3),
            format!("{:.3}", bp50 / nr * 1e3),
            format!("{:.3}", bmax / nr * 1e3),
            format!("{:.0}", gi / nr),
            format!("{:.0}", gb / nr),
            format!("{:.0}", th / nr),
        ]);
    }
    t
}

// =====================================================================
// svc_chaos — fault-injected PFS under the retry/deadline plane (PR 8)
// =====================================================================
//
// PR 8's acceptance scenario: concurrent sessions read through a PFS
// that injects transient read errors and runs two straggler OSTs, with
// the reliability plane (deadlines, backoff re-admission, optional
// hedging) turned on. Every session's close callback must fire exactly
// once and carry a SessionOutcome whose served/degraded split accounts
// for every byte; the governor must hold no residue at quiescence no
// matter which attempts failed, timed out, or raced teardown.

/// Results of one `run_svc_chaos` run.
#[derive(Clone, Debug)]
pub struct ChaosStats {
    /// The transient-fault probability the run injected.
    pub fault_p: f64,
    pub makespan_s: f64,
    /// Bytes served with real data, summed over the session outcomes.
    pub served_bytes: u64,
    /// Bytes degraded to modeled chunks, summed over session outcomes.
    pub degraded_bytes: u64,
    /// served / (served + degraded) — the goodput fraction.
    pub goodput: f64,
    /// Session-close callbacks observed (acceptance: == sessions).
    pub closes: u32,
    /// Reliability-plane effort, from the engine counters.
    pub retries: u64,
    pub timeouts: u64,
    pub hedges: u64,
    pub gave_up: u64,
    pub late: u64,
    /// Injected-fault counts, from the PFS model counters.
    pub faults_transient: u64,
    pub faults_persistent: u64,
    pub faults_short: u64,
    pub straggler_rpcs: u64,
    /// Governor tickets/demand reclaimed from torn-down buffers.
    pub reclaimed: u64,
}

/// Two OSTs served at `multiplier`× normal speed for the whole run —
/// the straggler schedule every chaos run shares.
fn chaos_stragglers(multiplier: f64) -> Vec<StragglerSpec> {
    [0u32, 1]
        .iter()
        .map(|&ost| StragglerSpec { ost, multiplier, from: 0, until: Time::MAX })
        .collect()
}

/// Drive `k` distinct-file sessions of `file_size` bytes (`clients`
/// client chares each) against a PFS injecting `transient_p` read
/// errors plus two straggler OSTs, with the retry plane configured via
/// `policy`. One governed shard and a tight admission cap keep the
/// ticket path — timeout-release, backoff re-admission, drop-time
/// reclaim — under real contention. Transient faults clear on retry by
/// definition, so with a sane attempt budget every byte is eventually
/// served and the outcomes' degraded side stays zero; persistent-fault
/// degradation is exercised by the chaos test suite instead.
#[allow(clippy::too_many_arguments)]
pub fn run_svc_chaos(
    nodes: u32,
    pes: u32,
    file_size: u64,
    k: u32,
    clients: u32,
    transient_p: f64,
    policy: RetryPolicy,
    seed: u64,
) -> (ChaosStats, CkIo, Engine) {
    assert!(k > 0 && clients > 0 && file_size >= clients as u64);
    let pfs = PfsConfig {
        noise_sigma: 0.0,
        rpc_overhead: time::from_micros(2.0),
        seek_penalty: 0,
        faults: FaultPlan {
            transient_p,
            stragglers: chaos_stragglers(8.0),
            ..Default::default()
        },
        ..PfsConfig::default()
    };
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed)).with_sim_pfs(pfs);
    let files: Vec<crate::pfs::FileId> =
        (0..k).map(|_| eng.core.sim_pfs_mut().create_file(file_size)).collect();
    let cfg = ServiceConfig {
        max_inflight_reads: Some(4),
        data_plane_shards: Some(1),
        retry: Some(policy),
        ..Default::default()
    };
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_chaos: valid ServiceConfig");
    let fopts = FileOptions::with_readers(2);
    let sopts = SessionOptions {
        splinter_bytes: Some(16 << 10),
        read_window: 8,
        ..Default::default()
    };
    let done_fut = eng.future(k);
    let outcome_fut = eng.future(k);
    let lat_fut = eng.future(k * clients);
    let per = file_size / clients as u64;
    let mut leaders = Vec::with_capacity(k as usize);
    for s in 0..k {
        let file = files[s as usize];
        let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
            let lo = i as u64 * per;
            let hi = if i == clients - 1 { file_size } else { lo + per };
            ConcurrentClient::new(
                io,
                file,
                file_size,
                i,
                clients,
                fopts.clone(),
                sopts.clone(),
                (lo, hi - lo),
                Callback::Future(done_fut),
                Callback::Future(lat_fut),
            )
        });
        for i in 0..clients {
            eng.chare_mut::<ConcurrentClient>(ChareRef::new(cid, i)).peers = cid;
        }
        eng.chare_mut::<ConcurrentClient>(ChareRef::new(cid, 0)).outcome =
            Some(Callback::Future(outcome_fut));
        leaders.push(ChareRef::new(cid, 0));
    }
    for leader in leaders {
        eng.inject_signal(leader, EP_CC_GO);
    }
    eng.run();
    assert!(eng.future_done(done_fut), "svc_chaos: not all sessions closed");
    assert!(eng.future_done(outcome_fut), "svc_chaos: a close ack lost its outcome");
    assert!(eng.future_done(lat_fut), "svc_chaos: not all reads completed");

    let done = eng.take_future(done_fut);
    let makespan = done.iter().map(|(t, _)| *t).max().unwrap();
    let outcomes: Vec<SessionOutcome> = eng
        .take_future(outcome_fut)
        .into_iter()
        .map(|(_, mut p)| p.take::<SessionOutcome>())
        .collect();
    let served: u64 = outcomes.iter().map(|o| o.served_bytes).sum();
    let degraded: u64 = outcomes.iter().map(|o| o.degraded_bytes).sum();
    let m = &eng.core.metrics;
    let stats = ChaosStats {
        fault_p: transient_p,
        makespan_s: time::to_secs(makespan),
        served_bytes: served,
        degraded_bytes: degraded,
        goodput: served as f64 / ((served + degraded) as f64).max(1.0),
        closes: outcomes.len() as u32,
        retries: m.counter(keys::RETRY_ATTEMPTS),
        timeouts: m.counter(keys::RETRY_TIMEOUTS),
        hedges: m.counter(keys::RETRY_HEDGES),
        gave_up: m.counter(keys::RETRY_GAVE_UP),
        late: m.counter(keys::RETRY_LATE),
        faults_transient: m.counter(keys::FAULT_TRANSIENT),
        faults_persistent: m.counter(keys::FAULT_PERSISTENT),
        faults_short: m.counter(keys::FAULT_SHORT),
        straggler_rpcs: m.counter(keys::FAULT_STRAGGLER),
        reclaimed: m.counter(keys::GOV_RECLAIMED),
    };
    (stats, io, eng)
}

/// The canonical svc_chaos shape — shared by the figure table, the
/// `BENCH_pr8.json` `reliability` section, and the acceptance test:
/// (nodes, pes, file_size, sessions, clients).
pub const CHAOS_SHAPE: (u32, u32, u64, u32, u32) = (2, 4, 256 << 10, 3, 4);

/// The transient-fault sweep every reporting surface shares.
pub const CHAOS_FAULT_SWEEP: [f64; 3] = [0.0, 0.05, 0.2];

/// The `svc_chaos` experiment table: goodput and retry effort vs the
/// injected transient-fault rate, plus a hedged row at the acceptance
/// rate (5%).
pub fn svc_chaos(reps: u32) -> Table {
    let (n, p, size, k, c) = CHAOS_SHAPE;
    let mut t = Table::new(
        format!(
            "svc_chaos: {k} sessions over distinct {} files, transient-fault sweep with two \
             8x straggler OSTs, one governed shard, cap 4 ({n} nodes x {p} PEs, {c} \
             clients/session; deadline+backoff retry, plus a hedged row at 5%)",
            crate::util::human_bytes(size),
        ),
        &[
            "mode",
            "fault_p",
            "makespan_ms",
            "goodput",
            "retries",
            "timeouts",
            "hedges",
            "gave_up",
        ],
    );
    let mut modes: Vec<(String, f64, RetryPolicy)> = CHAOS_FAULT_SWEEP
        .iter()
        .map(|&fp| ("retry".to_string(), fp, RetryPolicy::default()))
        .collect();
    modes.push(("hedged".to_string(), 0.05, RetryPolicy::default().with_hedging()));
    for (mode, fp, policy) in modes {
        let mut mk = 0.0;
        let mut gp = 0.0;
        let mut re = 0.0;
        let mut to = 0.0;
        let mut he = 0.0;
        let mut gu = 0.0;
        for r in 0..reps.max(1) {
            let (st, io, eng) =
                run_svc_chaos(n, p, size, k, c, fp, policy, 9800 + r as u64);
            assert_service_clean(&eng, &io);
            assert_eq!(st.closes, k, "svc_chaos: close callbacks != sessions");
            mk += st.makespan_s;
            gp += st.goodput;
            re += st.retries as f64;
            to += st.timeouts as f64;
            he += st.hedges as f64;
            gu += st.gave_up as f64;
        }
        let nr = reps.max(1) as f64;
        t.row(vec![
            mode,
            format!("{fp:.2}"),
            format!("{:.3}", mk / nr * 1e3),
            format!("{:.4}", gp / nr),
            format!("{:.0}", re / nr),
            format!("{:.0}", to / nr),
            format!("{:.0}", he / nr),
            format!("{:.0}", gu / nr),
        ]);
    }
    t
}

/// Machine-readable perf anchor for this PR (`BENCH_pr8.json`):
///
/// * `concurrent` — the PR 1 svc_concurrent aggregate-GiB/s anchor
///   (continuity: same shape and seeds as `BENCH_pr1.json`),
/// * `shared` — svc_shared PFS-dedup figures with the `ckio.store.*`
///   metrics (counters land in the engine-global sink, so with many
///   shards they are the sum over shards, and the resident gauge is
///   maintained as add-deltas — no silent shard-0-only reporting),
/// * `governed` — a capped run recording `ckio.governor.throttled` and
///   the PFS's observed max concurrent reads,
/// * `evict` — a reuse run under a tight store budget recording
///   `ckio.store.evicted_bytes` and the resident-bytes gauge,
/// * `churn` (PR 3) — the svc_churn shard sweep: makespan and the
///   per-shard message imbalance pair dropping as shards increase, with
///   shards=1 reproducing the PR 2 single-plane behavior,
/// * `feedback` (PR 3) — an `adaptive_admission` run recording the
///   AIMD-derived `ckio.governor.cap` and its adaptation count,
/// * `locality` (PR 4) — the svc_locality pair: K successive same-file
///   sessions under StoreAware vs SpreadNodes placement, with the
///   `ckio.place.*` counters showing cross-PE peer-fetch bytes
///   collapsing toward zero when placement follows the store,
/// * `qos` (PR 5) — the svc_qos classed-vs-classless pair: Interactive
///   and Bulk sessions contending on one governed shard under a tight
///   cap, with the `ckio.governor.class_granted.*` counters, the
///   Interactive p50 improvement over the classless baseline, and the
///   no-starvation quiescence checks (`governor_inflight` /
///   `governor_queued` both 0),
/// * `latency` (PR 7) — p50/p99/p99.9 (milliseconds) over the classed
///   qos run from the engine-global histograms: session makespan,
///   per-class admission wait, PFS read service, assembly, peer fetch,
/// * `reliability` (PR 8) — the svc_chaos transient-fault sweep under
///   two straggler OSTs: goodput and makespan vs fault rate with the
///   `ckio.retry.*` effort counters and `ckio.fault.*` injection
///   counts, plus a hedged run at the 5% acceptance rate.
pub fn bench_pr8_json(reps: u32) -> String {
    use crate::harness::bench::Json;
    let (nodes, pes) = (4u32, 8u32);
    let size = mib(256);
    let (clients, readers) = (32u32, 8u32);
    let n = reps.max(1) as f64;

    let mut concurrent = Vec::new();
    for &k in &[1u32, 4, 8] {
        let mut agg = 0.0;
        let mut p99 = 0.0;
        let mut mk = 0.0;
        for r in 0..reps.max(1) {
            let (st, _, _) = run_svc_concurrent(
                nodes,
                pes,
                size,
                k,
                clients,
                ServiceConfig::default(),
                FileOptions::with_readers(readers),
                SessionOptions::default(),
                8100 + r as u64,
            );
            agg += st.aggregate_gibs;
            p99 += st.read_p99_s;
            mk += st.makespan_s;
        }
        concurrent.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("aggregate_gibs", Json::num(agg / n)),
            ("read_p99_s", Json::num(p99 / n)),
            ("makespan_s", Json::num(mk / n)),
        ]));
    }

    let mut shared = Vec::new();
    let mut base_bytes = 0.0f64;
    for &k in &[1u32, 4] {
        let mut pfs = 0.0;
        let mut hit = 0.0;
        let mut miss = 0.0;
        let mut agg = 0.0;
        for r in 0..reps.max(1) {
            let (st, _, _) = run_svc_shared(
                nodes,
                pes,
                size,
                k,
                clients,
                ServiceConfig::default(),
                FileOptions::with_readers(readers),
                SessionOptions::default(),
                8200 + r as u64,
            );
            pfs += st.pfs_bytes_read as f64;
            hit += st.store_hit_bytes as f64;
            miss += st.store_miss_bytes as f64;
            agg += st.aggregate_gibs;
        }
        if k == 1 {
            base_bytes = pfs / n;
        }
        shared.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("pfs_bytes_read", Json::num(pfs / n)),
            ("pfs_bytes_ratio", Json::num((pfs / n) / base_bytes)),
            (keys::STORE_HIT, Json::num(hit / n)),
            (keys::STORE_MISS, Json::num(miss / n)),
            ("aggregate_gibs", Json::num(agg / n)),
        ]));
    }

    // Governed run: cap aggregate in-flight PFS reads at 4 across K = 4
    // sessions and record how much demand the governor deferred.
    let governed = {
        let cfg = ServiceConfig { max_inflight_reads: Some(4), ..Default::default() };
        let (st, _, eng) = run_svc_shared(
            nodes,
            pes,
            size,
            4,
            clients,
            cfg,
            FileOptions::with_readers(readers),
            SessionOptions::default(),
            8300,
        );
        Json::obj(vec![
            ("k", Json::num(4.0)),
            ("max_inflight_reads", Json::num(4.0)),
            (keys::GOV_THROTTLED, Json::num(st.governor_throttled as f64)),
            (
                "pfs_max_concurrent_reads",
                Json::num(eng.core.metrics.value(keys::PFS_MAX_CONCURRENT)),
            ),
            ("makespan_s", Json::num(st.makespan_s)),
        ])
    };

    // Eviction run: reuse + a one-array budget, so K parked arrays force
    // LRU eviction and exercise the byte accounting. Pinned to one shard
    // so the budget is not split (the PR 2 single-plane semantics).
    let evict = {
        let cfg = ServiceConfig {
            store_budget_bytes: Some(size),
            data_plane_shards: Some(1),
            ..Default::default()
        };
        let sopts = SessionOptions { reuse_buffers: true, ..Default::default() };
        let (st, _, eng) = run_svc_shared(
            nodes,
            pes,
            size,
            4,
            clients,
            cfg,
            FileOptions::with_readers(readers),
            sopts,
            8400,
        );
        Json::obj(vec![
            ("k", Json::num(4.0)),
            ("store_budget_bytes", Json::num(size as f64)),
            (keys::STORE_EVICTED, Json::num(st.store_evicted_bytes as f64)),
            (keys::STORE_RESIDENT, Json::num(eng.core.metrics.value(keys::STORE_RESIDENT))),
        ])
    };

    // Churn sweep: K distinct-file sessions vs the shard count (the one
    // canonical sweep, shared with the `svc_churn` figure). The shards=1
    // row is the PR 2 single-plane behavior; makespan and the max/mean
    // message imbalance both drop as shards increase.
    let churn: Vec<Json> = churn_sweep(reps)
        .into_iter()
        .map(|row| {
            Json::obj(vec![
                ("shards", Json::num(row.shards as f64)),
                ("k", Json::num(row.k as f64)),
                ("makespan_s", Json::num(row.makespan_s)),
                (keys::SHARD_MSGS_MAX, Json::num(row.shard_msgs_max)),
                (keys::SHARD_MSGS_MEAN, Json::num(row.shard_msgs_mean)),
            ])
        })
        .collect();

    // Feedback run: no static cap — the per-shard governor derives one
    // from observed service times (AIMD) and the gauge records where it
    // settled.
    let feedback = {
        let cfg = ServiceConfig {
            adaptive_admission: true,
            data_plane_shards: Some(1),
            ..Default::default()
        };
        let sopts = SessionOptions { splinter_bytes: Some(4 << 20), ..Default::default() };
        let (st, _, eng) = run_svc_shared(
            nodes,
            pes,
            size,
            4,
            clients,
            cfg,
            FileOptions::with_readers(readers),
            sopts,
            8600,
        );
        Json::obj(vec![
            ("k", Json::num(4.0)),
            (keys::GOV_CAP, Json::num(eng.core.metrics.value(keys::GOV_CAP))),
            (
                keys::GOV_ADAPTATIONS,
                Json::num(eng.core.metrics.counter(keys::GOV_ADAPTATIONS) as f64),
            ),
            (keys::GOV_THROTTLED, Json::num(st.governor_throttled as f64)),
            (
                "pfs_max_concurrent_reads",
                Json::num(eng.core.metrics.value(keys::PFS_MAX_CONCURRENT)),
            ),
            ("makespan_s", Json::num(st.makespan_s)),
        ])
    };

    // Locality pair (PR 4): the identical K-session overlapping workload
    // under store-aware vs spread placement. Deterministic (noise-free
    // PFS), so single seeded runs suffice, like governed/evict/feedback.
    let locality = {
        let (lk, lreaders, lsize) = (4u32, 8u32, mib(4));
        let side = |placement: ReaderPlacement| {
            let (st, _, _) = run_svc_locality(2, 4, lsize, lk, lreaders, placement, 8700);
            (
                st.cross_pe_fetch_bytes,
                Json::obj(vec![
                    (keys::PLACE_PLANNED, Json::num(st.planned as f64)),
                    (keys::PLACE_DEGRADED, Json::num(st.degraded as f64)),
                    (keys::PLACE_SAME_PE, Json::num(st.same_pe_fetch_bytes as f64)),
                    (keys::PLACE_CROSS_PE, Json::num(st.cross_pe_fetch_bytes as f64)),
                    (keys::STORE_HIT, Json::num(st.store_hit_bytes as f64)),
                    ("makespan_s", Json::num(st.makespan_s)),
                ]),
            )
        };
        let (sa_cross, store_aware) = side(store_aware_spread());
        let (sp_cross, spread) = side(ReaderPlacement::SpreadNodes);
        Json::obj(vec![
            ("k", Json::num(lk as f64)),
            ("readers", Json::num(lreaders as f64)),
            ("file_bytes", Json::num(lsize as f64)),
            ("store_aware", store_aware),
            ("spread", spread),
            (
                "cross_pe_reduction",
                Json::num(sp_cross as f64 / (sa_cross as f64).max(1.0)),
            ),
        ])
    };

    // QoS pair (PR 5): the identical Interactive+Bulk contention
    // workload with and without classes. Deterministic (noise-free
    // PFS), so a single seeded pair suffices, like governed/evict.
    let qos = {
        let (qn, qp, qsize, ni, nb, qc, cap) = QOS_SHAPE;
        let (classed, classless) = qos_pair(9000);
        let side = |st: &QosStats| {
            Json::obj(vec![
                ("interactive_p50_s", Json::num(st.interactive_p50_s)),
                ("bulk_p50_s", Json::num(st.bulk_p50_s)),
                ("bulk_max_s", Json::num(st.bulk_max_s)),
                ("makespan_s", Json::num(st.makespan_s)),
                (keys::GOV_GRANTED_INTERACTIVE, Json::num(st.granted_interactive as f64)),
                (keys::GOV_GRANTED_BULK, Json::num(st.granted_bulk as f64)),
                (keys::GOV_GRANTED_SCAVENGER, Json::num(st.granted_scavenger as f64)),
                (keys::GOV_THROTTLED, Json::num(st.throttled as f64)),
                ("governor_inflight", Json::num(st.governor_inflight as f64)),
                ("governor_queued", Json::num(st.governor_queued as f64)),
            ])
        };
        Json::obj(vec![
            ("nodes", Json::num(qn as f64)),
            ("pes_per_node", Json::num(qp as f64)),
            ("file_bytes", Json::num(qsize as f64)),
            ("interactive_sessions", Json::num(ni as f64)),
            ("bulk_sessions", Json::num(nb as f64)),
            ("clients_per_session", Json::num(qc as f64)),
            ("max_inflight_reads", Json::num(cap as f64)),
            ("classed", side(&classed)),
            ("classless", side(&classless)),
            (
                "interactive_p50_improvement",
                Json::num(classless.interactive_p50_s / classed.interactive_p50_s.max(1e-12)),
            ),
        ])
    };

    // Latency distributions (PR 7): p50/p99/p99.9 in milliseconds from
    // the engine-global mergeable histograms, measured over the classed
    // qos run — the same shape and seed as `qos.classed` above, so the
    // two sections can never silently measure different experiments.
    // Under the saturated cap the weighted governor should show
    // Interactive admission-wait p99 below Bulk's.
    let latency = {
        let (qn, qp, qsize, ni, nb, qc, cap) = QOS_SHAPE;
        let (_, io, eng) = run_svc_qos(qn, qp, qsize, ni, nb, qc, cap, true, false, 9000);
        assert_service_clean(&eng, &io);
        let m = &eng.core.metrics;
        let dist = |key: &'static str| {
            Json::obj(vec![
                ("p50", Json::num(m.quantile(key, 0.50) as f64 / 1e6)),
                ("p99", Json::num(m.quantile(key, 0.99) as f64 / 1e6)),
                ("p99.9", Json::num(m.quantile(key, 0.999) as f64 / 1e6)),
            ])
        };
        Json::obj(vec![
            ("unit", Json::str("ms")),
            (keys::LATENCY_SESSION_MAKESPAN, dist(keys::LATENCY_SESSION_MAKESPAN)),
            (
                keys::LATENCY_ADMISSION_WAIT_INTERACTIVE,
                dist(keys::LATENCY_ADMISSION_WAIT_INTERACTIVE),
            ),
            (keys::LATENCY_ADMISSION_WAIT_BULK, dist(keys::LATENCY_ADMISSION_WAIT_BULK)),
            (
                keys::LATENCY_ADMISSION_WAIT_SCAVENGER,
                dist(keys::LATENCY_ADMISSION_WAIT_SCAVENGER),
            ),
            (keys::LATENCY_PFS_READ, dist(keys::LATENCY_PFS_READ)),
            (keys::LATENCY_ASSEMBLY, dist(keys::LATENCY_ASSEMBLY)),
            (keys::LATENCY_PEER_FETCH, dist(keys::LATENCY_PEER_FETCH)),
        ])
    };

    // Reliability sweep (PR 8): goodput vs injected transient-fault
    // rate under two straggler OSTs, with the retry/hedge effort
    // counters. Deterministic (seeded faults, noise-free PFS), so
    // single seeded runs suffice, like governed/evict/feedback.
    let reliability = {
        let (cn, cp, csize, ck, cc) = CHAOS_SHAPE;
        let side = |st: &ChaosStats| {
            Json::obj(vec![
                ("fault_p", Json::num(st.fault_p)),
                ("makespan_s", Json::num(st.makespan_s)),
                ("goodput", Json::num(st.goodput)),
                ("served_bytes", Json::num(st.served_bytes as f64)),
                (keys::SESSION_DEGRADED, Json::num(st.degraded_bytes as f64)),
                (keys::RETRY_ATTEMPTS, Json::num(st.retries as f64)),
                (keys::RETRY_TIMEOUTS, Json::num(st.timeouts as f64)),
                (keys::RETRY_HEDGES, Json::num(st.hedges as f64)),
                (keys::RETRY_GAVE_UP, Json::num(st.gave_up as f64)),
                (keys::RETRY_LATE, Json::num(st.late as f64)),
                (keys::FAULT_TRANSIENT, Json::num(st.faults_transient as f64)),
                (keys::FAULT_STRAGGLER, Json::num(st.straggler_rpcs as f64)),
                (keys::GOV_RECLAIMED, Json::num(st.reclaimed as f64)),
            ])
        };
        let sweep: Vec<Json> = CHAOS_FAULT_SWEEP
            .iter()
            .map(|&fp| {
                let (st, io, eng) =
                    run_svc_chaos(cn, cp, csize, ck, cc, fp, RetryPolicy::default(), 9900);
                assert_service_clean(&eng, &io);
                assert_eq!(st.closes, ck, "reliability: close callbacks != sessions");
                side(&st)
            })
            .collect();
        let hedged = {
            let (st, io, eng) = run_svc_chaos(
                cn,
                cp,
                csize,
                ck,
                cc,
                0.05,
                RetryPolicy::default().with_hedging(),
                9900,
            );
            assert_service_clean(&eng, &io);
            side(&st)
        };
        Json::obj(vec![
            ("sessions", Json::num(ck as f64)),
            ("clients_per_session", Json::num(cc as f64)),
            ("file_bytes", Json::num(csize as f64)),
            ("straggler_osts", Json::num(2.0)),
            ("sweep", Json::arr(sweep)),
            ("hedged", hedged),
        ])
    };

    Json::obj(vec![
        (
            "bench",
            Json::str("svc_chaos+svc_qos+svc_locality+svc_churn+svc_shared+svc_concurrent"),
        ),
        ("pr", Json::num(8.0)),
        ("nodes", Json::num(nodes as f64)),
        ("pes_per_node", Json::num(pes as f64)),
        ("file_bytes", Json::num(size as f64)),
        ("clients_per_session", Json::num(clients as f64)),
        ("readers", Json::num(readers as f64)),
        ("concurrent", Json::arr(concurrent)),
        ("shared", Json::arr(shared)),
        ("governed", governed),
        ("evict", evict),
        ("churn", Json::arr(churn)),
        ("feedback", feedback),
        ("locality", locality),
        ("qos", qos),
        ("latency", latency),
        ("reliability", reliability),
    ])
    .render()
}

// =====================================================================
// svc_overlap — consumer-side locality (flow-matrix-driven migration)
// and I/O-aware overlap of admission waits (PR 9)
// =====================================================================

const EP_OC_GO: Ep = 40;
const EP_OC_OPENED: Ep = 41;
const EP_OC_SESSION: Ep = 42;
const EP_OC_DATA: Ep = 43;
const EP_OC_SLICE_DONE: Ep = 44;
const EP_OC_CLOSED: Ep = 45;
const EP_OC_FCLOSED: Ep = 46;

/// A migratable CkIO consumer for the locality/overlap experiments.
/// Element 0 opens the file, starts the session over the given range,
/// broadcasts the handle, and (once every peer reports) closes session
/// and file. Every element re-reads its fixed subrange `rounds` times —
/// the steady-state delivery pattern the flow matrix observes — and,
/// when the session runs [`ConsumerPlacement::FlowAware`], heeds the
/// director's `EP_CONSUMER_ADVICE` by migrating to the advised PE, after
/// which its piece deliveries become PE-local.
pub struct OverlapClient {
    io: CkIo,
    file: crate::pfs::FileId,
    file_size: u64,
    index: u32,
    n_peers: u32,
    /// Set post-creation by the driver.
    pub peers: CollectionId,
    fopts: FileOptions,
    sopts: SessionOptions,
    session_offset: u64,
    session_bytes: u64,
    my_offset: u64,
    my_len: u64,
    rounds: u32,
    rounds_done: u32,
    session: Option<Session>,
    go_time: Time,
    slices_done: u32,
    /// Advice messages acted on (the consumer was elsewhere and moved).
    pub advices_heeded: u32,
    /// Leader: fired with the session's elapsed `Time` after file close.
    session_done: Callback,
}

impl OverlapClient {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        io: CkIo,
        file: crate::pfs::FileId,
        file_size: u64,
        index: u32,
        n_peers: u32,
        fopts: FileOptions,
        sopts: SessionOptions,
        session_range: (u64, u64),
        slice: (u64, u64),
        rounds: u32,
        session_done: Callback,
    ) -> OverlapClient {
        OverlapClient {
            io,
            file,
            file_size,
            index,
            n_peers,
            peers: CollectionId(u32::MAX),
            fopts,
            sopts,
            session_offset: session_range.0,
            session_bytes: session_range.1,
            my_offset: slice.0,
            my_len: slice.1,
            rounds,
            rounds_done: 0,
            session: None,
            go_time: 0,
            slices_done: 0,
            advices_heeded: 0,
            session_done,
        }
    }

    fn issue_round(&mut self, ctx: &mut Ctx<'_>) {
        let s = self.session.expect("round issued before session arrived");
        let me = ctx.me();
        let (io, off, len) = (self.io, self.my_offset, self.my_len);
        io.read(ctx, &s, off, len, Callback::to_chare(me, EP_OC_DATA));
    }
}

impl Chare for OverlapClient {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_OC_GO => {
                self.go_time = ctx.now();
                let me = ctx.me();
                let (io, file, size, fopts) =
                    (self.io, self.file, self.file_size, self.fopts.clone());
                io.open(ctx, file, size, fopts, Callback::to_chare(me, EP_OC_OPENED));
            }
            EP_OC_OPENED => {
                let me = ctx.me();
                let (io, file, off, bytes, sopts) = (
                    self.io,
                    self.file,
                    self.session_offset,
                    self.session_bytes,
                    self.sopts.clone(),
                );
                io.start_read_session(
                    ctx,
                    file,
                    off,
                    bytes,
                    sopts,
                    Callback::to_chare(me, EP_OC_SESSION),
                );
            }
            EP_OC_SESSION => {
                let s: Session = msg.take();
                if self.index == 0 && self.session.is_none() {
                    for j in 1..self.n_peers {
                        ctx.send(ChareRef::new(self.peers, j), EP_OC_SESSION, s);
                    }
                }
                self.session = Some(s);
                self.issue_round(ctx);
            }
            EP_OC_DATA => {
                let r: ReadResult = msg.take();
                debug_assert_eq!(r.len, self.my_len);
                self.rounds_done += 1;
                if self.rounds_done < self.rounds {
                    self.issue_round(ctx);
                } else {
                    ctx.send(ChareRef::new(self.peers, 0), EP_OC_SLICE_DONE, ());
                }
            }
            EP_OC_SLICE_DONE => {
                self.slices_done += 1;
                if self.slices_done == self.n_peers {
                    let sid = self.session.as_ref().expect("leader has session").id;
                    let me = ctx.me();
                    let io = self.io;
                    io.close_read_session(ctx, sid, Callback::to_chare(me, EP_OC_CLOSED));
                }
            }
            EP_OC_CLOSED => {
                let _o: SessionOutcome = msg.take();
                let me = ctx.me();
                let (io, file) = (self.io, self.file);
                io.close(ctx, file, Callback::to_chare(me, EP_OC_FCLOSED));
            }
            EP_OC_FCLOSED => {
                let elapsed = ctx.now() - self.go_time;
                let done = self.session_done.clone();
                ctx.fire(done, Payload::new(elapsed));
            }
            EP_CONSUMER_ADVICE => {
                let m: ConsumerAdviceMsg = msg.take();
                if m.to_pe != ctx.pe().0 {
                    self.advices_heeded += 1;
                    ctx.migrate_me(Pe(m.to_pe));
                }
            }
            other => panic!("OverlapClient: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// [`OverlapClient`]'s declared message protocol (see
/// [`crate::amt::protocol`]). Open/file-close acks are `Any` (library
/// payloads, ignored here); the session-close ack decodes the structured
/// [`SessionOutcome`]; `EP_CONSUMER_ADVICE` is the director's flow-aware
/// migration advice (declared in `ckio/session.rs`).
pub fn overlap_client_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "OverlapClient",
        module: "harness/experiments.rs",
        handles: vec![
            ep_spec!(EP_OC_GO, PayloadKind::Signal),
            ep_spec!(EP_OC_OPENED, PayloadKind::Any),
            ep_spec!(EP_OC_SESSION, PayloadKind::of::<Session>()),
            ep_spec!(EP_OC_DATA, PayloadKind::of::<ReadResult>()),
            ep_spec!(EP_OC_SLICE_DONE, PayloadKind::Signal),
            ep_spec!(EP_OC_CLOSED, PayloadKind::of::<SessionOutcome>()),
            ep_spec!(EP_OC_FCLOSED, PayloadKind::Any),
            ep_spec!(EP_CONSUMER_ADVICE, PayloadKind::of::<ConsumerAdviceMsg>()),
        ],
        sends: vec![
            send_spec!("OverlapClient", EP_OC_SESSION, PayloadKind::of::<Session>()),
            send_spec!("OverlapClient", EP_OC_SLICE_DONE, PayloadKind::Signal),
        ],
    }
}

/// The fixed `svc_overlap` workload shape:
/// (nodes, pes/node, file bytes, consumers per session, rounds).
///
/// Two sessions over one shared 4 MiB file on 2×4 PEs. Each session's
/// consumers sit on the low PEs while its buffers are pinned to the high
/// PEs, so under [`ConsumerPlacement::Static`] every delivered piece
/// byte crosses PEs — the worst case the flow matrix is built to fix.
pub const OVERLAP_SHAPE: (u32, u32, u64, u32, u32) = (2, 4, 4 << 20, 2, 16);

/// Results of one [`run_svc_overlap`] run.
#[derive(Clone, Debug)]
pub struct OverlapStats {
    pub same_pe_piece_bytes: u64,
    pub cross_pe_piece_bytes: u64,
    pub flow_reports: u64,
    pub advised: u64,
    pub suppressed: u64,
    /// Engine-wide chare migrations (`amt.migrations`).
    pub migrations: u64,
    pub overlap_windows: u64,
    pub overlap_bg_iters: u64,
    pub overlap_bg_s: f64,
    pub overlap_window_s: f64,
    /// Total background iterations (inside waits or not); 0 without bg.
    pub bg_total_iters: u64,
    pub makespan_s: f64,
}

/// Drive the [`OVERLAP_SHAPE`] workload: 2 sessions × 2 consumers over
/// one shared file, consumers re-reading fixed buffer-local subranges so
/// every read delivers exactly one piece from one (pinned) buffer PE.
/// `placement` selects static vs flow-aware consumer placement; `cfg`
/// selects the governor (a tight cap opens admission-wait windows on the
/// buffer PEs); `with_bg` adds one quota-mode [`BgWorker`] per PE whose
/// iterations inside open windows land in the `ckio.overlap.*` counters.
pub fn run_svc_overlap(
    placement: ConsumerPlacement,
    cfg: ServiceConfig,
    with_bg: bool,
    seed: u64,
) -> (OverlapStats, CkIo, Engine) {
    let (nodes, pes, file_size, consumers, rounds) = OVERLAP_SHAPE;
    let npes = nodes * pes;
    let sessions = 2u32;
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
        .with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_overlap: valid ServiceConfig");

    let bg_fut = if with_bg {
        let fut = eng.future(npes);
        let grp =
            eng.create_group(|_| BgWorker::new(10 * MICROS, Some(5_000), Callback::Future(fut)));
        for pe in 0..npes {
            eng.inject_signal(ChareRef::new(grp, pe), EP_BG_START);
        }
        Some(fut)
    } else {
        None
    };

    let done_fut = eng.future(sessions);
    let fopts = FileOptions::with_readers(consumers);
    let sess_bytes = file_size / sessions as u64;
    let span = sess_bytes / consumers as u64;
    let read_len = span / 4;
    let mut leaders = Vec::with_capacity(sessions as usize);
    for s in 0..sessions {
        let sess_off = s as u64 * sess_bytes;
        // Consumers on the low PEs, their session's buffers pinned to
        // the high PEs: under Static placement every piece crosses.
        let consumer_pes: Vec<Pe> = (0..consumers).map(|i| Pe(s * consumers + i)).collect();
        let buffer_pes: Vec<u32> =
            (0..consumers).map(|i| sessions * consumers + s * consumers + i).collect();
        let sopts = SessionOptions {
            splinter_bytes: Some(128 << 10),
            placement_override: Some(ReaderPlacement::Explicit(buffer_pes)),
            consumer_placement: placement,
            ..Default::default()
        };
        let fo = fopts.clone();
        let cid = eng.create_array(consumers, &Placement::Explicit(consumer_pes), |i| {
            OverlapClient::new(
                io,
                file,
                file_size,
                i,
                consumers,
                fo.clone(),
                sopts.clone(),
                (sess_off, sess_bytes),
                (sess_off + i as u64 * span, read_len),
                rounds,
                Callback::Future(done_fut),
            )
        });
        eng.register_protocol(cid, overlap_client_protocol_spec());
        for i in 0..consumers {
            eng.chare_mut::<OverlapClient>(ChareRef::new(cid, i)).peers = cid;
        }
        leaders.push(ChareRef::new(cid, 0));
    }
    for leader in leaders {
        eng.inject_signal(leader, EP_OC_GO);
    }
    eng.run();
    assert!(eng.future_done(done_fut), "svc_overlap: not all sessions closed");

    let done = eng.take_future(done_fut);
    let makespan = done.iter().map(|(t, _)| *t).max().unwrap();
    let bg_total_iters = match bg_fut {
        Some(fut) => {
            assert!(eng.future_done(fut), "svc_overlap: background quota unfinished");
            eng.take_future(fut).into_iter().map(|(_, mut p)| p.take::<u64>()).sum::<u64>()
        }
        None => 0,
    };
    let (windows, bg_iters, bg_ns, window_ns) = eng.core.overlap_totals();
    let m = &eng.core.metrics;
    let stats = OverlapStats {
        same_pe_piece_bytes: m.counter(keys::PLACE_PIECE_SAME_PE),
        cross_pe_piece_bytes: m.counter(keys::PLACE_PIECE_CROSS_PE),
        flow_reports: m.counter(keys::CONSUMER_FLOW_REPORTS),
        advised: m.counter(keys::CONSUMER_MIGRATIONS_ADVISED),
        suppressed: m.counter(keys::CONSUMER_ADVICE_SUPPRESSED),
        migrations: m.counter(keys::MIGRATIONS),
        overlap_windows: windows,
        overlap_bg_iters: bg_iters,
        overlap_bg_s: time::to_secs(bg_ns),
        overlap_window_s: time::to_secs(window_ns),
        bg_total_iters,
        makespan_s: time::to_secs(makespan),
    };
    (stats, io, eng)
}

/// The `svc_overlap` experiment table: the four legs of the PR 9 story —
/// static vs flow-aware consumer placement (ungoverned), then a tightly
/// governed run with and without background work to show admission
/// waits being overlapped. Deterministic (noise-free PFS), so `reps`
/// would only repeat identical numbers; kept for CLI uniformity.
pub fn svc_overlap(reps: u32) -> Table {
    let _ = reps;
    let (nodes, pes, file_size, consumers, rounds) = OVERLAP_SHAPE;
    let mut t = Table::new(
        &format!(
            "svc_overlap: consumer locality + I/O-aware overlap ({nodes}x{pes} PEs, {} shared \
             file, 2 sessions x {consumers} consumers x {rounds} rounds)",
            crate::util::human_bytes(file_size)
        ),
        &[
            "leg",
            "same_pe_mib",
            "cross_pe_mib",
            "reports",
            "advised",
            "suppressed",
            "migrations",
            "windows",
            "bg_iters_in_wait",
            "bg_in_wait_ms",
            "makespan_ms",
        ],
    );
    let governed = ServiceConfig {
        max_inflight_reads: Some(1),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let flow = ConsumerPlacement::FlowAware { piece_threshold: 2, migration_budget: 4 };
    let legs: Vec<(&str, ConsumerPlacement, ServiceConfig, bool, u64)> = vec![
        ("static", ConsumerPlacement::Static, ServiceConfig::default(), false, 9100),
        ("flow_aware", flow, ServiceConfig::default(), false, 9100),
        ("governed+bg", ConsumerPlacement::Static, governed.clone(), true, 9200),
        ("governed", ConsumerPlacement::Static, governed, false, 9200),
    ];
    for (leg, placement, cfg, with_bg, seed) in legs {
        let (st, io, eng) = run_svc_overlap(placement, cfg, with_bg, seed);
        assert_service_clean(&eng, &io);
        t.row(vec![
            leg.to_string(),
            format!("{:.2}", st.same_pe_piece_bytes as f64 / (1u64 << 20) as f64),
            format!("{:.2}", st.cross_pe_piece_bytes as f64 / (1u64 << 20) as f64),
            st.flow_reports.to_string(),
            st.advised.to_string(),
            st.suppressed.to_string(),
            st.migrations.to_string(),
            st.overlap_windows.to_string(),
            st.overlap_bg_iters.to_string(),
            format!("{:.3}", st.overlap_bg_s * 1e3),
            format!("{:.3}", st.makespan_s * 1e3),
        ]);
    }
    t
}

/// Emit the PR 9 machine-readable perf anchor (`BENCH_pr9.json`): the
/// consumer-locality pair (static vs flow-aware placement, with the
/// flow-matrix counters and the cross-PE piece-byte reduction) and the
/// admission-wait overlap pair (the tightly governed run with and
/// without background work, with the `ckio.overlap.*` counters). Both
/// acceptance claims are asserted here as well as in the test suite, so
/// a regressed build fails the CI bench smoke too.
pub fn bench_pr9_json(reps: u32) -> String {
    use crate::harness::bench::Json;
    let _ = reps; // deterministic seeded runs — repetition adds nothing
    let (nodes, pes, file_size, consumers, rounds) = OVERLAP_SHAPE;

    let side = |st: &OverlapStats| {
        Json::obj(vec![
            (keys::PLACE_PIECE_SAME_PE, Json::num(st.same_pe_piece_bytes as f64)),
            (keys::PLACE_PIECE_CROSS_PE, Json::num(st.cross_pe_piece_bytes as f64)),
            (keys::CONSUMER_FLOW_REPORTS, Json::num(st.flow_reports as f64)),
            (keys::CONSUMER_MIGRATIONS_ADVISED, Json::num(st.advised as f64)),
            (keys::CONSUMER_ADVICE_SUPPRESSED, Json::num(st.suppressed as f64)),
            (keys::MIGRATIONS, Json::num(st.migrations as f64)),
            ("makespan_s", Json::num(st.makespan_s)),
        ])
    };
    let consumer_locality = {
        let flow = ConsumerPlacement::FlowAware { piece_threshold: 2, migration_budget: 4 };
        let (st, io_s, eng_s) =
            run_svc_overlap(ConsumerPlacement::Static, ServiceConfig::default(), false, 9100);
        assert_service_clean(&eng_s, &io_s);
        let (fa, io_f, eng_f) = run_svc_overlap(flow, ServiceConfig::default(), false, 9100);
        assert_service_clean(&eng_f, &io_f);
        let reduction =
            1.0 - fa.cross_pe_piece_bytes as f64 / st.cross_pe_piece_bytes.max(1) as f64;
        assert!(
            reduction >= 0.5,
            "flow-aware placement must cut cross-PE piece bytes by >= 50%, got {reduction:.3}"
        );
        Json::obj(vec![
            ("static", side(&st)),
            ("flow_aware", side(&fa)),
            ("cross_pe_reduction", Json::num(reduction)),
        ])
    };

    let oside = |st: &OverlapStats| {
        Json::obj(vec![
            (keys::OVERLAP_WINDOWS, Json::num(st.overlap_windows as f64)),
            (keys::OVERLAP_BG_ITERS, Json::num(st.overlap_bg_iters as f64)),
            (keys::OVERLAP_BG_TIME, Json::num(st.overlap_bg_s)),
            (keys::OVERLAP_WINDOW_TIME, Json::num(st.overlap_window_s)),
            ("bg_total_iters", Json::num(st.bg_total_iters as f64)),
            ("makespan_s", Json::num(st.makespan_s)),
        ])
    };
    let overlap = {
        let governed = ServiceConfig {
            max_inflight_reads: Some(1),
            data_plane_shards: Some(1),
            ..Default::default()
        };
        let (bg, io_a, eng_a) =
            run_svc_overlap(ConsumerPlacement::Static, governed.clone(), true, 9200);
        assert_service_clean(&eng_a, &io_a);
        let (nobg, io_b, eng_b) =
            run_svc_overlap(ConsumerPlacement::Static, governed, false, 9200);
        assert_service_clean(&eng_b, &io_b);
        assert!(
            bg.overlap_windows > 0 && bg.overlap_bg_iters > 0,
            "governed run must measure background iterations inside admission waits"
        );
        Json::obj(vec![
            ("max_inflight_reads", Json::num(1.0)),
            ("with_bg", oside(&bg)),
            ("without_bg", oside(&nobg)),
        ])
    };

    Json::obj(vec![
        ("bench", Json::str("svc_overlap")),
        ("pr", Json::num(9.0)),
        ("nodes", Json::num(nodes as f64)),
        ("pes_per_node", Json::num(pes as f64)),
        ("file_bytes", Json::num(file_size as f64)),
        ("sessions", Json::num(2.0)),
        ("consumers_per_session", Json::num(consumers as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("consumer_locality", consumer_locality),
        ("overlap", overlap),
    ])
    .render()
}

// =====================================================================
// svc_rw — collective output plane: write, flush, close, then read the
// same bytes back from residency (PR 10)
// =====================================================================

const EP_RW_GO: Ep = 50;
const EP_RW_OPENED: Ep = 51;
const EP_RW_WSESSION: Ep = 52;
const EP_RW_WROTE: Ep = 53;
const EP_RW_WDONE: Ep = 54;
const EP_RW_FLUSHED: Ep = 55;
const EP_RW_WCLOSED: Ep = 56;
const EP_RW_RSESSION: Ep = 57;
const EP_RW_RDATA: Ep = 58;
const EP_RW_RDONE: Ep = 59;
const EP_RW_RCLOSED: Ep = 60;
const EP_RW_FCLOSED: Ep = 61;

/// One producer/consumer of the read-after-write workload. Element 0
/// leads: open → `startWriteSession` → broadcast; every element
/// scatters its slice as `piece_bytes`-sized puts; the leader then
/// runs the flush barrier (skipped when the session parks dirty),
/// closes the write session and — with `read_back` — starts a read
/// session over the same range, which the parked write residency must
/// serve without a single PFS read. Readers verify the delivered
/// bytes against the file pattern, so "served from residency" is also
/// "byte-identical with what was written".
pub struct RwClient {
    io: CkIo,
    file: crate::pfs::FileId,
    file_size: u64,
    index: u32,
    n_peers: u32,
    /// Set post-creation by the driver.
    pub peers: CollectionId,
    fopts: FileOptions,
    sopts: SessionOptions,
    wopts: WriteOptions,
    piece_bytes: u64,
    my_offset: u64,
    my_len: u64,
    /// Leader: run the flush barrier before closing the write session.
    flush: bool,
    /// Leader: follow the write with a read session over the range.
    read_back: bool,
    wsession: Option<Session>,
    rsession: Option<Session>,
    written: u64,
    received: u64,
    wdone: u32,
    rdone: u32,
    go_time: Time,
    read_start: Time,
    /// Leader: fired with the write phase's elapsed `Time` once the
    /// write session is closed.
    write_done: Callback,
    /// Leader: fired with the write close's [`SessionOutcome`].
    outcome: Callback,
    /// Leader: fired at file close with the read phase's elapsed
    /// `Time` (0 when `read_back` is off).
    done: Callback,
}

impl RwClient {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        io: CkIo,
        file: crate::pfs::FileId,
        file_size: u64,
        index: u32,
        n_peers: u32,
        fopts: FileOptions,
        sopts: SessionOptions,
        wopts: WriteOptions,
        piece_bytes: u64,
        slice: (u64, u64),
        flush: bool,
        read_back: bool,
        write_done: Callback,
        outcome: Callback,
        done: Callback,
    ) -> RwClient {
        assert!(piece_bytes > 0, "piece granularity must be positive");
        RwClient {
            io,
            file,
            file_size,
            index,
            n_peers,
            peers: CollectionId(u32::MAX),
            fopts,
            sopts,
            wopts,
            piece_bytes,
            my_offset: slice.0,
            my_len: slice.1,
            flush,
            read_back,
            wsession: None,
            rsession: None,
            written: 0,
            received: 0,
            wdone: 0,
            rdone: 0,
            go_time: 0,
            read_start: 0,
            write_done,
            outcome,
            done,
        }
    }

    /// Scatter this producer's slice as piece-sized puts.
    fn scatter(&mut self, ctx: &mut Ctx<'_>) {
        let s = self.wsession.expect("scatter before the write session arrived");
        let me = ctx.me();
        let io = self.io;
        let end = self.my_offset + self.my_len;
        let mut o = self.my_offset;
        while o < end {
            let l = self.piece_bytes.min(end - o);
            io.write(ctx, &s, o, l, Callback::to_chare(me, EP_RW_WROTE));
            o += l;
        }
    }
}

impl Chare for RwClient {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_RW_GO => {
                self.go_time = ctx.now();
                let me = ctx.me();
                let (io, file, size, fopts) =
                    (self.io, self.file, self.file_size, self.fopts.clone());
                io.open(ctx, file, size, fopts, Callback::to_chare(me, EP_RW_OPENED));
            }
            EP_RW_OPENED => {
                let me = ctx.me();
                let (io, file, size, sopts, wopts) =
                    (self.io, self.file, self.file_size, self.sopts.clone(), self.wopts);
                io.start_write_session(
                    ctx,
                    file,
                    0,
                    size,
                    sopts,
                    wopts,
                    Callback::to_chare(me, EP_RW_WSESSION),
                );
            }
            EP_RW_WSESSION => {
                let s: Session = msg.take();
                if self.index == 0 && self.wsession.is_none() {
                    for j in 1..self.n_peers {
                        ctx.send(ChareRef::new(self.peers, j), EP_RW_WSESSION, s);
                    }
                }
                self.wsession = Some(s);
                if self.my_len == 0 {
                    ctx.send(ChareRef::new(self.peers, 0), EP_RW_WDONE, ());
                    return;
                }
                self.scatter(ctx);
            }
            EP_RW_WROTE => {
                let r: WriteResult = msg.take();
                self.written += r.len;
                if self.written == self.my_len {
                    ctx.send(ChareRef::new(self.peers, 0), EP_RW_WDONE, ());
                }
            }
            EP_RW_WDONE => {
                self.wdone += 1;
                if self.wdone == self.n_peers {
                    let sid = self.wsession.as_ref().expect("leader has write session").id;
                    let me = ctx.me();
                    let io = self.io;
                    if self.flush {
                        io.flush_write_session(ctx, sid, Callback::to_chare(me, EP_RW_FLUSHED));
                    } else {
                        io.close_write_session(ctx, sid, Callback::to_chare(me, EP_RW_WCLOSED));
                    }
                }
            }
            EP_RW_FLUSHED => {
                let sid = self.wsession.as_ref().expect("leader has write session").id;
                let me = ctx.me();
                let io = self.io;
                io.close_write_session(ctx, sid, Callback::to_chare(me, EP_RW_WCLOSED));
            }
            EP_RW_WCLOSED => {
                let o: SessionOutcome = msg.take();
                let elapsed = ctx.now() - self.go_time;
                let wcb = self.write_done.clone();
                ctx.fire(wcb, Payload::new(elapsed));
                let ocb = self.outcome.clone();
                ctx.fire(ocb, Payload::new(o));
                let me = ctx.me();
                let (io, file, size, sopts) =
                    (self.io, self.file, self.file_size, self.sopts.clone());
                if self.read_back {
                    self.read_start = ctx.now();
                    io.start_read_session(
                        ctx,
                        file,
                        0,
                        size,
                        sopts,
                        Callback::to_chare(me, EP_RW_RSESSION),
                    );
                } else {
                    io.close(ctx, file, Callback::to_chare(me, EP_RW_FCLOSED));
                }
            }
            EP_RW_RSESSION => {
                let s: Session = msg.take();
                if self.index == 0 && self.rsession.is_none() {
                    for j in 1..self.n_peers {
                        ctx.send(ChareRef::new(self.peers, j), EP_RW_RSESSION, s);
                    }
                }
                self.rsession = Some(s);
                if self.my_len == 0 {
                    ctx.send(ChareRef::new(self.peers, 0), EP_RW_RDONE, ());
                    return;
                }
                let me = ctx.me();
                let (io, off, len) = (self.io, self.my_offset, self.my_len);
                io.read(ctx, &s, off, len, Callback::to_chare(me, EP_RW_RDATA));
            }
            EP_RW_RDATA => {
                let r: ReadResult = msg.take();
                debug_assert_eq!(r.len, self.my_len);
                // The byte-identity half of the acceptance claim: the
                // residency-served chunk regenerates exactly the
                // pattern the producers wrote.
                let bytes =
                    r.chunk.bytes.as_ref().expect("read-after-write must deliver materialized bytes");
                assert_eq!(
                    crate::pfs::pattern::verify(self.file, r.offset, bytes),
                    None,
                    "read-after-write bytes differ from what was written"
                );
                self.received += r.len;
                if self.received == self.my_len {
                    ctx.send(ChareRef::new(self.peers, 0), EP_RW_RDONE, ());
                }
            }
            EP_RW_RDONE => {
                self.rdone += 1;
                if self.rdone == self.n_peers {
                    let sid = self.rsession.as_ref().expect("leader has read session").id;
                    let me = ctx.me();
                    let io = self.io;
                    io.close_read_session(ctx, sid, Callback::to_chare(me, EP_RW_RCLOSED));
                }
            }
            EP_RW_RCLOSED => {
                let _o: SessionOutcome = msg.take();
                let me = ctx.me();
                let (io, file) = (self.io, self.file);
                io.close(ctx, file, Callback::to_chare(me, EP_RW_FCLOSED));
            }
            EP_RW_FCLOSED => {
                let read_elapsed =
                    if self.read_back { ctx.now() - self.read_start } else { 0 };
                let done = self.done.clone();
                ctx.fire(done, Payload::new(read_elapsed));
            }
            other => panic!("RwClient: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// [`RwClient`]'s declared message protocol (see
/// [`crate::amt::protocol`]). Open / flush / file-close acks are `Any`
/// (library payloads, ignored or empty); both session-close acks decode
/// the structured [`SessionOutcome`].
pub fn rw_client_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "RwClient",
        module: "harness/experiments.rs",
        handles: vec![
            ep_spec!(EP_RW_GO, PayloadKind::Signal),
            ep_spec!(EP_RW_OPENED, PayloadKind::Any),
            ep_spec!(EP_RW_WSESSION, PayloadKind::of::<Session>()),
            ep_spec!(EP_RW_WROTE, PayloadKind::of::<WriteResult>()),
            ep_spec!(EP_RW_WDONE, PayloadKind::Signal),
            ep_spec!(EP_RW_FLUSHED, PayloadKind::Any),
            ep_spec!(EP_RW_WCLOSED, PayloadKind::of::<SessionOutcome>()),
            ep_spec!(EP_RW_RSESSION, PayloadKind::of::<Session>()),
            ep_spec!(EP_RW_RDATA, PayloadKind::of::<ReadResult>()),
            ep_spec!(EP_RW_RDONE, PayloadKind::Signal),
            ep_spec!(EP_RW_RCLOSED, PayloadKind::of::<SessionOutcome>()),
            ep_spec!(EP_RW_FCLOSED, PayloadKind::Any),
        ],
        sends: vec![
            send_spec!("RwClient", EP_RW_WSESSION, PayloadKind::of::<Session>()),
            send_spec!("RwClient", EP_RW_WDONE, PayloadKind::Signal),
            send_spec!("RwClient", EP_RW_RSESSION, PayloadKind::of::<Session>()),
            send_spec!("RwClient", EP_RW_RDONE, PayloadKind::Signal),
        ],
    }
}

/// Results of one [`run_svc_rw`] run.
#[derive(Clone, Debug)]
pub struct RwStats {
    /// Open → write session closed.
    pub write_makespan_s: f64,
    /// Read session start → file closed (0 without `read_back`).
    pub read_makespan_s: f64,
    /// PFS write RPCs over the whole run (the aggregation numerator).
    pub pfs_write_rpcs: u64,
    pub pfs_bytes_written: u64,
    /// PFS read bytes over the WHOLE run — the headline: 0 means the
    /// read-back session never touched the PFS.
    pub rw_pfs_read_bytes: u64,
    /// Bytes the read session resolved against resident claims.
    pub store_hit_bytes: u64,
    pub puts: u64,
    pub extents: u64,
    pub flushes: u64,
    /// Dirty-span evictions/purges that forced a writeback (lazy mode).
    pub dirty_writebacks: u64,
    pub dirty_writeback_bytes: u64,
    pub retries: u64,
    pub degraded_bytes: u64,
    /// The write session's close outcome (exactly one close callback).
    pub outcome: SessionOutcome,
}

/// Drive one write session of `file_size` bytes scattered by `clients`
/// producers in `piece_bytes` puts, then (with `read_back`) one read
/// session over the same range, served from the parked write
/// residency. `flush` runs the barrier before close; `transient_p`
/// injects PR 8 transient faults (they apply to write RPCs too).
#[allow(clippy::too_many_arguments)]
pub fn run_svc_rw(
    nodes: u32,
    pes: u32,
    file_size: u64,
    clients: u32,
    piece_bytes: u64,
    cfg: ServiceConfig,
    fopts: FileOptions,
    wopts: WriteOptions,
    flush: bool,
    read_back: bool,
    transient_p: f64,
    seed: u64,
) -> (RwStats, CkIo, Engine) {
    assert!(clients > 0 && file_size >= clients as u64);
    let pfs = PfsConfig {
        noise_sigma: 0.0,
        materialize: true,
        faults: FaultPlan { transient_p, ..Default::default() },
        ..PfsConfig::default()
    };
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed)).with_sim_pfs(pfs);
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot_with(&mut eng, cfg).expect("svc_rw: valid ServiceConfig");
    let wdone_fut = eng.future(1);
    let outcome_fut = eng.future(1);
    let done_fut = eng.future(1);
    let per = file_size / clients as u64;
    let sopts = SessionOptions::default();
    let cid = eng.create_array(clients, &Placement::RoundRobinPes, |i| {
        let lo = i as u64 * per;
        let hi = if i == clients - 1 { file_size } else { lo + per };
        RwClient::new(
            io,
            file,
            file_size,
            i,
            clients,
            fopts.clone(),
            sopts.clone(),
            wopts,
            piece_bytes,
            (lo, hi - lo),
            flush,
            read_back,
            Callback::Future(wdone_fut),
            Callback::Future(outcome_fut),
            Callback::Future(done_fut),
        )
    });
    eng.register_protocol(cid, rw_client_protocol_spec());
    for i in 0..clients {
        eng.chare_mut::<RwClient>(ChareRef::new(cid, i)).peers = cid;
    }
    eng.inject_signal(ChareRef::new(cid, 0), EP_RW_GO);
    eng.run();
    assert!(eng.future_done(wdone_fut), "svc_rw: write session did not close");
    assert!(eng.future_done(outcome_fut), "svc_rw: write close lost its outcome");
    assert!(eng.future_done(done_fut), "svc_rw: the file was never closed");

    let write_makespan: Time =
        eng.take_future(wdone_fut).into_iter().map(|(_, mut p)| p.take::<Time>()).sum();
    let outcome: SessionOutcome = eng
        .take_future(outcome_fut)
        .into_iter()
        .map(|(_, mut p)| p.take::<SessionOutcome>())
        .next()
        .expect("exactly one write close outcome");
    let read_makespan: Time =
        eng.take_future(done_fut).into_iter().map(|(_, mut p)| p.take::<Time>()).sum();
    let m = &eng.core.metrics;
    let stats = RwStats {
        write_makespan_s: time::to_secs(write_makespan),
        read_makespan_s: time::to_secs(read_makespan),
        pfs_write_rpcs: m.counter(keys::PFS_WRITE_RPCS),
        pfs_bytes_written: m.counter(keys::PFS_BYTES_WRITTEN),
        rw_pfs_read_bytes: m.counter(keys::PFS_BYTES),
        store_hit_bytes: m.counter(keys::STORE_HIT),
        puts: m.counter(keys::WRITE_PUTS),
        extents: m.counter(keys::WRITE_EXTENTS),
        flushes: m.counter(keys::WRITE_FLUSHES),
        dirty_writebacks: m.counter(keys::STORE_DIRTY_WRITEBACKS),
        dirty_writeback_bytes: m.counter(keys::STORE_DIRTY_WRITEBACK_BYTES),
        retries: m.counter(keys::RETRY_ATTEMPTS),
        degraded_bytes: m.counter(keys::WRITE_DEGRADED),
        outcome,
    };
    (stats, io, eng)
}

/// The naive write baseline: `writers` producers, each writing its
/// slice of `file_size` bytes straight to the PFS one `piece_bytes`
/// RPC at a time (no aggregation, no striping, no admission). Returns
/// (PFS write RPCs, PFS bytes written, makespan seconds, engine).
pub fn run_naive_write(
    nodes: u32,
    pes: u32,
    file_size: u64,
    writers: u32,
    piece_bytes: u64,
    seed: u64,
) -> (u64, u64, f64, Engine) {
    assert!(writers > 0 && file_size >= writers as u64);
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(seed))
        .with_sim_pfs(PfsConfig { noise_sigma: 0.0, ..PfsConfig::default() });
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let per = file_size / writers as u64;
    let fut = eng.future(writers);
    let cid = eng.create_array(writers, &Placement::RoundRobinPes, |i| {
        let lo = i as u64 * per;
        let hi = if i == writers - 1 { file_size } else { lo + per };
        NaiveWriter::new(file, lo, hi - lo, piece_bytes, Callback::Future(fut))
    });
    eng.register_protocol(cid, naive_writer_protocol_spec());
    for i in 0..writers {
        eng.inject_signal(ChareRef::new(cid, i), EP_W_GO);
    }
    eng.run();
    assert!(eng.future_done(fut), "naive write did not complete");
    let makespan = eng.take_future(fut).iter().map(|(t, _)| *t).max().unwrap();
    let m = &eng.core.metrics;
    (
        m.counter(keys::PFS_WRITE_RPCS),
        m.counter(keys::PFS_BYTES_WRITTEN),
        time::to_secs(makespan),
        eng,
    )
}

/// The canonical svc_rw shape — shared by the figure table, the
/// `BENCH_pr10.json` `write` section, and the acceptance test:
/// (nodes, pes, file_size, producers, piece_bytes).
pub const RW_SHAPE: (u32, u32, u64, u32, u64) = (2, 4, 8 << 20, 8, 64 << 10);

/// The `svc_rw` experiment table: the naive per-producer write baseline
/// against the aggregated write plane (eager write-behind + flush, and
/// the lazy park-dirty mode whose PFS writes happen only at the
/// purge-forced writeback), with the read-after-write residency
/// columns. Deterministic (noise-free PFS), so `reps` only repeats
/// identical numbers; kept for CLI uniformity.
pub fn svc_rw(reps: u32) -> Table {
    let _ = reps;
    let (nodes, pes, size, clients, piece) = RW_SHAPE;
    let mut t = Table::new(
        format!(
            "svc_rw: collective write + read-after-write from residency ({nodes}x{pes} PEs, \
             {} x {clients} producers, {} pieces, 1 MiB stripes; reduction = naive write RPCs \
             / leg write RPCs, rw_pfs_read_bytes must be 0 on read-back legs)",
            crate::util::human_bytes(size),
            crate::util::human_bytes(piece),
        ),
        &[
            "leg",
            "write_rpcs",
            "reduction",
            "mib_written",
            "rw_pfs_read_bytes",
            "hit_mib",
            "write_ms",
            "read_ms",
        ],
    );
    let (naive_rpcs, naive_bytes, naive_s, _) =
        run_naive_write(nodes, pes, size, clients, piece, 10_100);
    t.row(vec![
        "naive".into(),
        naive_rpcs.to_string(),
        "1.00".into(),
        format!("{:.1}", naive_bytes as f64 / (1u64 << 20) as f64),
        "-".into(),
        "-".into(),
        format!("{:.3}", naive_s * 1e3),
        "-".into(),
    ]);
    let legs: Vec<(&str, WriteOptions, bool)> = vec![
        ("ckio", WriteOptions::default(), true),
        ("ckio_lazy", WriteOptions::lazy(), false),
    ];
    for (leg, wopts, flush) in legs {
        let (st, io, eng) = run_svc_rw(
            nodes,
            pes,
            size,
            clients,
            piece,
            ServiceConfig::default(),
            FileOptions::with_readers(4),
            wopts,
            flush,
            true,
            0.0,
            10_100,
        );
        assert_service_clean(&eng, &io);
        assert_eq!(st.rw_pfs_read_bytes, 0, "svc_rw {leg}: read-back touched the PFS");
        t.row(vec![
            leg.to_string(),
            st.pfs_write_rpcs.to_string(),
            format!("{:.2}", naive_rpcs as f64 / st.pfs_write_rpcs.max(1) as f64),
            format!("{:.1}", st.pfs_bytes_written as f64 / (1u64 << 20) as f64),
            st.rw_pfs_read_bytes.to_string(),
            format!("{:.1}", st.store_hit_bytes as f64 / (1u64 << 20) as f64),
            format!("{:.3}", st.write_makespan_s * 1e3),
            format!("{:.3}", st.read_makespan_s * 1e3),
        ]);
    }
    t
}

/// Emit the PR 10 machine-readable perf anchor (`BENCH_pr10.json`):
/// the `write` section (naive per-producer baseline vs the aggregated
/// stripe-coalesced plane, with the write-op reduction), the
/// `read_after_write` section (the headline `rw_pfs_read_bytes: 0`
/// residency claim, byte-verified), the `lazy_writeback` section
/// (park-dirty close, purge-forced writeback accounting) and the
/// `write_chaos` section (flush barrier + exactly-once close under
/// injected write faults). The acceptance claims are asserted here as
/// well as in the test suite, so a regressed build fails the CI bench
/// smoke too.
pub fn bench_pr10_json(reps: u32) -> String {
    use crate::harness::bench::Json;
    let _ = reps; // deterministic seeded runs — repetition adds nothing
    let (nodes, pes, size, clients, piece) = RW_SHAPE;

    let (naive_rpcs, naive_bytes, naive_s, _) =
        run_naive_write(nodes, pes, size, clients, piece, 10_100);

    let (agg, io_a, eng_a) = run_svc_rw(
        nodes,
        pes,
        size,
        clients,
        piece,
        ServiceConfig::default(),
        FileOptions::with_readers(4),
        WriteOptions::default(),
        true,
        true,
        0.0,
        10_100,
    );
    assert_service_clean(&eng_a, &io_a);
    let reduction = naive_rpcs as f64 / agg.pfs_write_rpcs.max(1) as f64;
    assert!(
        reduction >= 4.0,
        "aggregated writes must issue >= 4x fewer PFS write RPCs than naive, got {reduction:.2}"
    );
    assert_eq!(agg.rw_pfs_read_bytes, 0, "read-after-write must not touch the PFS");
    let write = Json::obj(vec![
        ("piece_bytes", Json::num(piece as f64)),
        ("stripe_bytes", Json::num(WriteOptions::default().stripe_bytes as f64)),
        (
            "naive",
            Json::obj(vec![
                (keys::PFS_WRITE_RPCS, Json::num(naive_rpcs as f64)),
                (keys::PFS_BYTES_WRITTEN, Json::num(naive_bytes as f64)),
                ("makespan_s", Json::num(naive_s)),
            ]),
        ),
        (
            "aggregated",
            Json::obj(vec![
                (keys::PFS_WRITE_RPCS, Json::num(agg.pfs_write_rpcs as f64)),
                (keys::PFS_BYTES_WRITTEN, Json::num(agg.pfs_bytes_written as f64)),
                (keys::WRITE_PUTS, Json::num(agg.puts as f64)),
                (keys::WRITE_EXTENTS, Json::num(agg.extents as f64)),
                (keys::WRITE_FLUSHES, Json::num(agg.flushes as f64)),
                ("written_bytes", Json::num(agg.outcome.written_bytes as f64)),
                ("makespan_s", Json::num(agg.write_makespan_s)),
            ]),
        ),
        ("write_op_reduction", Json::num(reduction)),
    ]);

    let read_after_write = Json::obj(vec![
        ("rw_pfs_read_bytes", Json::num(agg.rw_pfs_read_bytes as f64)),
        (keys::STORE_HIT, Json::num(agg.store_hit_bytes as f64)),
        ("read_makespan_s", Json::num(agg.read_makespan_s)),
        ("byte_verified", Json::num(1.0)),
    ]);

    // Lazy mode: close parks dirty, the read is still served from
    // residency, and the PFS writes happen only when the file close
    // purges the parked span (the forced-writeback path).
    let lazy_writeback = {
        let (st, io, eng) = run_svc_rw(
            nodes,
            pes,
            size,
            clients,
            piece,
            ServiceConfig::default(),
            FileOptions::with_readers(4),
            WriteOptions::lazy(),
            false,
            true,
            0.0,
            10_200,
        );
        assert_service_clean(&eng, &io);
        assert_eq!(st.rw_pfs_read_bytes, 0, "lazy read-back must not touch the PFS");
        assert!(st.dirty_writebacks > 0, "purging a dirty park must force a writeback");
        Json::obj(vec![
            ("dirty_bytes_at_close", Json::num(st.outcome.dirty_bytes as f64)),
            (keys::STORE_DIRTY_WRITEBACKS, Json::num(st.dirty_writebacks as f64)),
            (keys::STORE_DIRTY_WRITEBACK_BYTES, Json::num(st.dirty_writeback_bytes as f64)),
            (keys::PFS_WRITE_RPCS, Json::num(st.pfs_write_rpcs as f64)),
            ("rw_pfs_read_bytes", Json::num(st.rw_pfs_read_bytes as f64)),
        ])
    };

    // Write chaos: transient faults apply to write RPCs; the flush
    // barrier and the exactly-once close still hold, and with a sane
    // retry budget every byte is durably written (degraded stays 0).
    let write_chaos = {
        let wopts = WriteOptions { stripe_bytes: 64 << 10, ..Default::default() };
        let cfg = ServiceConfig {
            max_inflight_reads: Some(4),
            data_plane_shards: Some(1),
            retry: Some(RetryPolicy::default()),
            ..Default::default()
        };
        let (st, io, eng) = run_svc_rw(
            nodes,
            pes,
            size,
            clients,
            piece,
            cfg,
            FileOptions::with_readers(4),
            wopts,
            true,
            false,
            0.2,
            10_300,
        );
        assert_service_clean(&eng, &io);
        assert_eq!(
            st.outcome.written_bytes,
            size,
            "transient write faults must clear on retry"
        );
        Json::obj(vec![
            ("fault_p", Json::num(0.2)),
            (keys::RETRY_ATTEMPTS, Json::num(st.retries as f64)),
            (keys::WRITE_DEGRADED, Json::num(st.degraded_bytes as f64)),
            ("written_bytes", Json::num(st.outcome.written_bytes as f64)),
            ("closes", Json::num(1.0)),
            ("makespan_s", Json::num(st.write_makespan_s)),
        ])
    };

    Json::obj(vec![
        ("bench", Json::str("svc_rw")),
        ("pr", Json::num(10.0)),
        ("nodes", Json::num(nodes as f64)),
        ("pes_per_node", Json::num(pes as f64)),
        ("file_bytes", Json::num(size as f64)),
        ("producers", Json::num(clients as f64)),
        ("write", write),
        ("read_after_write", read_after_write),
        ("lazy_writeback", lazy_writeback),
        ("write_chaos", write_chaos),
    ])
    .render()
}

// =====================================================================
// §VI.A ablation — automatic reader-count policy vs manual sweep
// =====================================================================

pub fn ablation_autoreaders(reps: u32) -> Table {
    let mut t = Table::new(
        "Ablation (SecVI.A): auto reader policy vs manual sweep (16x32 PEs, 512 clients)",
        &["file", "best_readers", "best_s", "auto_readers", "auto_s", "auto_penalty"],
    );
    for &size in &[mib(256), gib(1), gib(4)] {
        let mut best = (0u32, f64::MAX);
        for readers in [16u32, 32, 64, 128, 256, 512] {
            let mean: f64 = (0..reps)
                .map(|r| {
                    time::to_secs(
                        run_ckio_read(
                            PAPER_NODES,
                            PAPER_PES,
                            size,
                            512,
                            FileOptions::with_readers(readers),
                            SessionOptions::default(),
                            5000 + r as u64,
                        )
                        .0,
                    )
                })
                .sum::<f64>()
                / reps as f64;
            if mean < best.1 {
                best = (readers, mean);
            }
        }
        let auto = crate::ckio::options::auto_readers(
            size,
            &crate::amt::topology::Topology::new(PAPER_NODES, PAPER_PES),
        );
        let auto_s: f64 = (0..reps)
            .map(|r| {
                time::to_secs(
                    run_ckio_read(
                        PAPER_NODES,
                        PAPER_PES,
                        size,
                        512,
                        FileOptions::with_readers(auto),
                        SessionOptions::default(),
                        6000 + r as u64,
                    )
                    .0,
                )
            })
            .sum::<f64>()
            / reps as f64;
        t.row(vec![
            crate::util::human_bytes(size),
            best.0.to_string(),
            format!("{:.3}", best.1),
            auto.to_string(),
            format!("{auto_s:.3}"),
            format!("{:.2}x", auto_s / best.1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ckio_and_naive_drivers_read_everything() {
        let (tn, eng_n) = run_naive_read(2, 4, 16 << 20, 16, false, 1);
        assert_eq!(eng_n.core.metrics.counter("pfs.bytes_read"), 16 << 20);
        let (tc, eng_c) = run_ckio_read(
            2,
            4,
            16 << 20,
            16,
            FileOptions::with_readers(8),
            SessionOptions::default(),
            1,
        );
        assert_eq!(eng_c.core.metrics.counter(keys::CKIO_BYTES), 16 << 20);
        assert!(tn > 0 && tc > 0);
    }

    #[test]
    fn fig2_gap_is_large() {
        let t = fig2_disk_vs_net(1);
        // Every size: reading beats... loses to the network by > 4x.
        for row in &t.rows {
            let ratio: f64 = row[3].trim_end_matches('x').parse().unwrap();
            assert!(ratio > 4.0, "disk/net ratio too small: {ratio}");
        }
    }

    #[test]
    fn fig12_locality_pays_off_at_large_sizes() {
        let (pre, post) = migration_run(1 << 30, 42);
        assert!(pre > post, "pre={pre} post={post}");
    }

    #[test]
    fn migration_run_small() {
        let (pre, post) = migration_run(64 << 20, 7);
        assert!(pre > 0.0 && post > 0.0);
    }

    /// PR 1 acceptance: K = 8 concurrent sessions (mixed same-file and
    /// distinct-file) run to completion with no panic and no stranded
    /// assembly/pending entries after all closes, and aggregate modeled
    /// throughput at K = 8 genuinely exceeds the single-session figure.
    /// The acceptance floor is 0.9x single x min(K, saturation point);
    /// at this shape the modeled PFS saturates (LNET/OST bound) well
    /// below 8x, but a director that *serialized* the sessions would
    /// score at most ~1.0x — so the bar below is what catches a
    /// lost-concurrency regression while staying clear of the modeled
    /// saturation ratio.
    #[test]
    fn svc_concurrent_scales_and_leaves_no_residue() {
        use crate::ckio::director::Director;

        let fopts = FileOptions::with_readers(4);
        let (s1, _, _) = run_svc_concurrent(
            2,
            4,
            32 << 20,
            1,
            4,
            ServiceConfig::default(),
            fopts.clone(),
            SessionOptions::default(),
            9,
        );
        let (s8, io, eng) = run_svc_concurrent(
            2,
            4,
            32 << 20,
            8,
            4,
            ServiceConfig::default(),
            fopts,
            SessionOptions::default(),
            9,
        );
        assert_eq!(s8.per_session_s.len(), 8);
        assert!(s8.read_p99_s > 0.0);
        assert!(
            s8.aggregate_gibs >= 1.05 * s1.aggregate_gibs,
            "aggregate at K=8 ({:.2} GiB/s) does not scale over single-session ({:.2} GiB/s): \
             concurrent sessions are being serialized",
            s8.aggregate_gibs,
            s1.aggregate_gibs
        );
        // Teardown left nothing behind anywhere in the service.
        assert_service_clean(&eng, &io);
        let director = eng.chare::<Director>(io.director);
        assert_eq!(director.open_files(), 0, "leaked file refcounts");
        assert_eq!(eng.core.metrics.counter("ckio.sessions"), 8);
        // Every session's every client read was delivered exactly once.
        assert_eq!(eng.core.metrics.counter(keys::CKIO_BYTES), 8 * (32 << 20));
    }

    /// PR 2 acceptance: K = 4 concurrent sessions over ONE file incur at
    /// most 1.25x the PFS bytes of a single session (vs ~4x before the
    /// span store), with the surplus served out of resident data.
    #[test]
    fn svc_shared_dedups_same_file_prefetch() {
        let size = 32 << 20;
        let fopts = FileOptions::with_readers(4);
        let (s1, _, _) = run_svc_shared(
            2,
            4,
            size,
            1,
            4,
            ServiceConfig::default(),
            fopts.clone(),
            SessionOptions::default(),
            11,
        );
        let (s4, io, eng) = run_svc_shared(
            2,
            4,
            size,
            4,
            4,
            ServiceConfig::default(),
            fopts,
            SessionOptions::default(),
            11,
        );
        assert!(s1.pfs_bytes_read >= size, "single session must read the file");
        assert!(
            s4.pfs_bytes_read as f64 <= 1.25 * s1.pfs_bytes_read as f64,
            "K=4 same-file sessions read {} from the PFS vs {} for one session: \
             prefetch dedup is not working",
            s4.pfs_bytes_read,
            s1.pfs_bytes_read
        );
        // The other 3 sessions' bytes came from the resident plane...
        assert!(
            s4.store_hit_bytes >= 3 * size - size / 4,
            "expected ~3 sessions' bytes served from the store, got {}",
            s4.store_hit_bytes
        );
        // ...and every session still delivered its full range.
        assert_eq!(eng.core.metrics.counter(keys::CKIO_BYTES), 4 * size);
        assert_service_clean(&eng, &io);
    }

    #[test]
    fn svc_shared_governed_run_caps_pfs_concurrency() {
        let cfg = ServiceConfig {
            max_inflight_reads: Some(2),
            data_plane_shards: Some(1),
            ..Default::default()
        };
        let sopts = SessionOptions { splinter_bytes: Some(1 << 20), ..Default::default() };
        let (st, io, eng) =
            run_svc_shared(2, 4, 16 << 20, 2, 4, cfg, FileOptions::with_readers(4), sopts, 13);
        assert!(st.governor_throttled > 0, "a 2-read cap must defer some demand");
        assert!(
            eng.core.metrics.value(keys::PFS_MAX_CONCURRENT) <= 2.0,
            "PFS saw more concurrent reads than the governor cap"
        );
        assert_eq!(eng.core.metrics.counter(keys::CKIO_BYTES), 2 * (16 << 20));
        assert_service_clean(&eng, &io);
    }

    #[test]
    fn bench_pr8_json_is_wellformed() {
        let j = bench_pr8_json(1);
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains(
            "\"bench\":\"svc_chaos+svc_qos+svc_locality+svc_churn+svc_shared+svc_concurrent\""
        ));
        assert!(j.contains("\"aggregate_gibs\""));
        // K = 1, 4, 8 all reported in the concurrent anchor.
        assert!(j.contains("\"k\":1") && j.contains("\"k\":4") && j.contains("\"k\":8"));
        // The store / governor / shard / placement / qos observability
        // keys the CI smoke greps for (PR 2 set + PR 3 churn/feedback +
        // PR 4 locality + the PR 5 qos additions).
        for key in [
            "ckio.store.hit_bytes",
            "ckio.store.miss_bytes",
            "ckio.store.evicted_bytes",
            "ckio.store.resident_bytes",
            "ckio.governor.throttled",
            "\"churn\"",
            "\"feedback\"",
            "\"shards\"",
            "ckio.shard.msgs_max",
            "ckio.shard.msgs_mean",
            "ckio.governor.cap",
            "ckio.governor.adaptations",
            "\"locality\"",
            "ckio.place.planned",
            "ckio.place.same_pe_fetch",
            "ckio.place.cross_pe_fetch",
            "ckio.place.degraded",
            "cross_pe_reduction",
            "\"qos\"",
            "ckio.governor.class_granted.interactive",
            "ckio.governor.class_granted.bulk",
            "ckio.governor.class_granted.scavenger",
            "interactive_p50_improvement",
            "governor_inflight",
            "governor_queued",
            // PR 7 latency distributions.
            "\"latency\"",
            "ckio.latency.session_makespan",
            "ckio.latency.admission_wait.interactive",
            "ckio.latency.admission_wait.bulk",
            "ckio.latency.admission_wait.scavenger",
            "ckio.latency.pfs_read_service",
            "ckio.latency.assembly",
            "ckio.latency.peer_fetch",
            "\"p50\"",
            "\"p99\"",
            "\"p99.9\"",
            // PR 8 reliability sweep.
            "\"reliability\"",
            "\"sweep\"",
            "\"hedged\"",
            "\"goodput\"",
            "\"fault_p\"",
            "ckio.retry.attempts",
            "ckio.retry.timeouts",
            "ckio.retry.hedges",
            "ckio.retry.gave_up",
            "ckio.retry.late_completions",
            "ckio.fault.transient",
            "ckio.fault.straggler_rpcs",
            "ckio.session.degraded_bytes",
            "ckio.governor.reclaimed",
        ] {
            assert!(j.contains(key), "missing {key} in BENCH_pr8 json");
        }
    }

    /// PR 5 acceptance: under a shared shard cap, the Interactive-class
    /// p50 session makespan beats the classless baseline while Bulk is
    /// not starved — every session completes and the governor holds no
    /// tickets or queued demand at quiescence. Deterministic
    /// (noise-free PFS, same seed and arrival interleaving both sides).
    #[test]
    fn svc_qos_interactive_beats_classless_without_starving_bulk() {
        let (classed, classless) = qos_pair(77);
        // The contended resource was really the governor queue.
        assert!(classed.throttled > 0 && classless.throttled > 0);
        // Grants split by weight only when classes are on.
        assert!(classed.granted_interactive > 0 && classed.granted_bulk > 0);
        assert_eq!(classless.granted_interactive, 0, "classless runs are all Bulk");
        assert_eq!(classed.granted_scavenger, 0);
        // The QoS claim: Interactive p50 strictly improves…
        assert!(
            classed.interactive_p50_s < classless.interactive_p50_s,
            "classed interactive p50 {:.6}s must beat classless {:.6}s",
            classed.interactive_p50_s,
            classless.interactive_p50_s
        );
        // …and Bulk is not starved: every Bulk session finished, and
        // nothing is parked in the governor at quiescence.
        assert_eq!(classed.bulk_s.len(), QOS_SHAPE.4 as usize);
        assert!(classed.bulk_max_s.is_finite() && classed.bulk_max_s > 0.0);
        assert_eq!(classed.governor_inflight, 0, "tickets leaked at quiescence");
        assert_eq!(classed.governor_queued, 0, "demand stranded at quiescence");
        assert_eq!(classless.governor_inflight, 0);
        assert_eq!(classless.governor_queued, 0);
    }

    /// PR 7 acceptance: under the saturated classed cap, the weighted
    /// governor holds the Interactive admission-wait p99 below Bulk's,
    /// and the engine-global latency histograms carry the session
    /// makespans (same shape and seed as the `latency` bench section).
    #[test]
    fn svc_qos_interactive_admission_wait_p99_beats_bulk() {
        let (qn, qp, qsize, ni, nb, qc, cap) = QOS_SHAPE;
        let (st, io, eng) = run_svc_qos(qn, qp, qsize, ni, nb, qc, cap, true, false, 9000);
        assert_service_clean(&eng, &io);
        assert!(st.throttled > 0, "the cap must saturate for admission waits to differ");
        let m = &eng.core.metrics;
        assert!(
            m.histogram(keys::LATENCY_ADMISSION_WAIT_INTERACTIVE).is_some()
                && m.histogram(keys::LATENCY_ADMISSION_WAIT_BULK).is_some(),
            "both classes must have recorded admission waits"
        );
        let pi = m.quantile(keys::LATENCY_ADMISSION_WAIT_INTERACTIVE, 0.99);
        let pb = m.quantile(keys::LATENCY_ADMISSION_WAIT_BULK, 0.99);
        assert!(
            pi < pb,
            "Interactive admission-wait p99 ({pi} ns) must be below Bulk's ({pb} ns)"
        );
        assert_eq!(
            m.histogram(keys::LATENCY_SESSION_MAKESPAN).map(|h| h.count()),
            Some((ni + nb) as u64),
            "every session's makespan must be recorded exactly once"
        );
    }

    /// PR 7 acceptance: a tiny traced run (the CLI `ckio trace` path:
    /// station-armed, engine deposits its sink on drop) exports Chrome
    /// trace-event JSON carrying session spans, per-class ticket spans,
    /// PFS RPC spans, and at least one cause-annotated AIMD cap change —
    /// and teardown leaves every begin paired with an end.
    #[test]
    fn traced_run_exports_chrome_trace_with_expected_spans() {
        use crate::trace::{self, names, TraceConfig};
        trace::arm(TraceConfig::on());
        let (qn, qp, qsize, ni, nb, qc, cap) = QOS_SHAPE;
        let (_, io, eng) = run_svc_qos(qn, qp, qsize, ni, nb, qc, cap, true, true, 9000);
        assert!(eng.core.trace.is_enabled(), "armed station must install a sink at boot");
        assert_service_clean(&eng, &io); // includes the open-span pairing check
        drop(eng); // deposits the sink back to this thread's station
        let sinks = trace::collect();
        trace::disarm();
        assert_eq!(sinks.len(), 1, "exactly one engine ran while armed");
        let json = trace::export_chrome(&sinks);
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        for needle in [
            "\"traceEvents\"",
            "\"displayTimeUnit\"",
            names::SESSION_ACTIVE,
            names::SESSION_OPEN,
            names::SESSION_CLOSE,
            names::TICKET_WAIT,
            names::PFS_READ,
            names::GOVERNOR_CAP,
            "interactive", // class-labelled ticket annotations
            "bulk",
        ] {
            assert!(json.contains(needle), "missing {needle} in exported trace");
        }
        // At least one AIMD cap change carries its cause annotation.
        assert!(
            json.contains("growth_probe") || json.contains("p50_inflation"),
            "adaptive run must export a cause-annotated governor/cap event"
        );
        // The category summary sees the same families.
        let counts = trace::category_counts(&sinks);
        assert!(counts.get("session").copied().unwrap_or(0) > 0);
        assert!(counts.get("ticket").copied().unwrap_or(0) > 0);
        assert!(counts.get("pfs").copied().unwrap_or(0) > 0);
    }

    /// PR 5 satellite (default-compatibility): `SessionOptions::default()`
    /// reproduces the explicit pre-redesign parameters byte-for-byte on
    /// the svc_concurrent workload — identical makespan, latency, and
    /// delivered bytes for the same seed.
    #[test]
    fn session_options_default_is_byte_for_byte_pre_redesign() {
        let explicit = SessionOptions {
            class: QosClass::Bulk,
            splinter_bytes: None,
            read_window: 2,
            reuse_buffers: false,
            placement_override: None,
            consumer_placement: ConsumerPlacement::Static,
        };
        let (sd, _, eng_d) = run_svc_concurrent(
            2,
            4,
            16 << 20,
            4,
            4,
            ServiceConfig::default(),
            FileOptions::with_readers(4),
            SessionOptions::default(),
            23,
        );
        let (se, _, eng_e) = run_svc_concurrent(
            2,
            4,
            16 << 20,
            4,
            4,
            ServiceConfig::default(),
            FileOptions::with_readers(4),
            explicit,
            23,
        );
        assert_eq!(sd.makespan_s, se.makespan_s, "default must not change timing");
        assert_eq!(sd.per_session_s, se.per_session_s);
        assert_eq!(sd.read_p99_s, se.read_p99_s);
        assert_eq!(
            eng_d.core.metrics.counter(keys::CKIO_BYTES),
            eng_e.core.metrics.counter(keys::CKIO_BYTES)
        );
        assert_eq!(
            eng_d.core.metrics.counter(keys::PFS_BYTES),
            eng_e.core.metrics.counter(keys::PFS_BYTES)
        );
    }

    /// PR 4 acceptance: under StoreAware placement the K successive
    /// overlapping sessions' peer fetches stay on-PE — cross-PE
    /// peer-fetch bytes collapse to zero for this aligned workload —
    /// while the identical workload under SpreadNodes pays real cross-PE
    /// traffic. Deterministic: noise-free PFS, aligned windows.
    #[test]
    fn svc_locality_store_aware_collapses_cross_pe_fetches() {
        let size = 4 << 20;
        let (sa, io_a, eng_a) = run_svc_locality(2, 4, size, 4, 8, store_aware_spread(), 31);
        let (sp, io_b, eng_b) =
            run_svc_locality(2, 4, size, 4, 8, ReaderPlacement::SpreadNodes, 31);
        assert_service_clean(&eng_a, &io_a);
        assert_service_clean(&eng_b, &io_b);
        // Both runs dedup the same resident bytes; every peer-fetched
        // byte is classified as exactly one of same-PE / cross-PE.
        assert!(sa.store_hit_bytes > 0 && sp.store_hit_bytes > 0);
        assert_eq!(sa.same_pe_fetch_bytes + sa.cross_pe_fetch_bytes, sa.store_hit_bytes);
        assert_eq!(sp.same_pe_fetch_bytes + sp.cross_pe_fetch_bytes, sp.store_hit_bytes);
        // StoreAware planned every overlapping buffer (3 sessions x 8),
        // nothing raced an unclaim, and every fetch stayed local.
        assert_eq!(sa.planned, 3 * 8, "every later buffer must be plan-placed");
        assert_eq!(sa.degraded, 0);
        assert_eq!(
            sa.cross_pe_fetch_bytes, 0,
            "store-aware placement must colocate every peer fetch in this aligned workload"
        );
        assert!(sa.same_pe_fetch_bytes > 0);
        // The spread baseline pays cross-PE for the same bytes.
        assert_eq!(sp.planned, 0);
        assert!(
            sp.cross_pe_fetch_bytes > 0,
            "the spread baseline must pay cross-PE peer fetches"
        );
    }

    /// PR 3 acceptance: K = 8 distinct-file sessions complete strictly
    /// faster as the data plane spreads from one shard to one per file,
    /// and the per-shard message load spreads with it. (Deterministic:
    /// the churn PFS shape runs noise-free, so the comparison is exact,
    /// not statistical.)
    #[test]
    fn svc_churn_scales_with_shards() {
        let mut mks = Vec::new();
        for &s in &[1u32, 2, 4, 8] {
            let (st, io, eng) = run_svc_churn(2, 4, 512 << 10, 8, 4, s, 21);
            assert_eq!(st.shards, s);
            assert_eq!(eng.core.metrics.counter("ckio.sessions"), 8);
            assert_eq!(eng.core.metrics.counter(keys::CKIO_BYTES), 8 * (512 << 10));
            assert_service_clean(&eng, &io);
            // Distinct files spread over the modulus: at 8 shards every
            // file has its own, so the max load is (near) the mean.
            if s == 8 {
                assert!(
                    st.shard_msgs_max as f64 <= 2.0 * st.shard_msgs_mean,
                    "8 distinct files on 8 shards must spread the load: max {} vs mean {:.0}",
                    st.shard_msgs_max,
                    st.shard_msgs_mean
                );
            }
            mks.push(st.makespan_s);
        }
        // Non-increasing with a 10% tolerance: once the shard work drops
        // below the (identical) I/O floor, adjacent configurations are
        // both floor-bound and may wobble by scheduling micro-shifts.
        for w in mks.windows(2) {
            assert!(
                w[1] <= w[0] * 1.10,
                "makespan must not grow with shards: {mks:?}"
            );
        }
        assert!(
            mks[3] < 0.8 * mks[0],
            "8 shards must clearly beat the single-shard (PR 2) data plane: {mks:?}"
        );
    }

    /// PR 3 satellite: with no static cap, `adaptive_admission` derives
    /// a per-shard cap from observed service times, the AIMD loop
    /// actually moves it, and admission still caps the PFS.
    #[test]
    fn adaptive_governor_derives_and_adapts_a_cap() {
        let cfg = ServiceConfig {
            adaptive_admission: true,
            data_plane_shards: Some(1),
            ..Default::default()
        };
        let sopts = SessionOptions { splinter_bytes: Some(512 << 10), ..Default::default() };
        let (st, io, eng) =
            run_svc_shared(2, 4, 16 << 20, 2, 4, cfg, FileOptions::with_readers(4), sopts, 17);
        // The loop ran: at least one cap change beyond the initial value.
        assert!(
            eng.core.metrics.counter(keys::GOV_ADAPTATIONS) > 0,
            "the AIMD loop never adapted the cap"
        );
        let cap = eng.core.metrics.value(keys::GOV_CAP);
        assert!(cap >= 1.0, "published cap must be at least the floor, got {cap}");
        // Admission was genuinely active from the derived cap's low
        // start: some demand must have been deferred.
        assert!(st.governor_throttled > 0, "an adaptive cap of 2 must defer early demand");
        assert_eq!(eng.core.metrics.counter(keys::CKIO_BYTES), 2 * (16 << 20));
        assert_service_clean(&eng, &io);
    }

    /// PR 9 acceptance (tentpole, consumer side): on the svc_overlap
    /// shape — consumers and pinned buffers on disjoint PEs — flow-aware
    /// placement advises every consumer toward its dominant source PE
    /// exactly once (hysteresis: no ping-pong), each migrates exactly
    /// once, and cross-PE piece bytes drop by at least 50% against the
    /// identical static run. The static side doubles as the satellite
    /// check that the `ckio.place.piece_*` metrics are always on: it
    /// counts every delivered byte as cross-PE with flow accounting
    /// never armed.
    #[test]
    fn svc_overlap_flow_aware_halves_cross_pe_piece_bytes() {
        let flow = ConsumerPlacement::FlowAware { piece_threshold: 2, migration_budget: 4 };
        let (st, io_s, eng_s) =
            run_svc_overlap(ConsumerPlacement::Static, ServiceConfig::default(), false, 29);
        let (fa, io_f, eng_f) = run_svc_overlap(flow, ServiceConfig::default(), false, 29);
        assert_service_clean(&eng_s, &io_s);
        assert_service_clean(&eng_f, &io_f);
        // Identical delivered work both sides; every piece byte is
        // classified as exactly one of same-PE / cross-PE.
        assert_eq!(
            st.same_pe_piece_bytes + st.cross_pe_piece_bytes,
            fa.same_pe_piece_bytes + fa.cross_pe_piece_bytes
        );
        assert_eq!(st.same_pe_piece_bytes, 0, "static: disjoint PEs, everything crosses");
        assert!(st.cross_pe_piece_bytes > 0);
        assert_eq!(st.flow_reports, 0, "static sessions must not arm flow accounting");
        assert_eq!(st.advised, 0);
        assert_eq!(st.migrations, 0);
        assert!(fa.flow_reports > 0);
        assert_eq!(fa.advised, 4, "each of the 4 consumers advised exactly once");
        assert_eq!(fa.migrations, 4, "each migration counted exactly once, no ping-pong");
        assert_eq!(fa.suppressed, 0, "budget 4 per session never binds here");
        assert!(fa.same_pe_piece_bytes > 0);
        assert!(
            fa.cross_pe_piece_bytes * 2 <= st.cross_pe_piece_bytes,
            "flow-aware must cut cross-PE piece bytes by >= 50%: {} vs {}",
            fa.cross_pe_piece_bytes,
            st.cross_pe_piece_bytes
        );
        // Ungoverned runs never queue demand: no admission-wait windows.
        assert_eq!(fa.overlap_windows, 0);
        assert_eq!(fa.overlap_bg_iters, 0);
    }

    /// PR 9 tentpole: the hard per-session migration budget. With a
    /// budget of 1, only one consumer per session is advised; the other
    /// keeps wanting to move and is counted as suppressed, never
    /// advised, and never migrates.
    #[test]
    fn migration_budget_and_hysteresis_bound_advice() {
        let flow = ConsumerPlacement::FlowAware { piece_threshold: 2, migration_budget: 1 };
        let (fa, io, eng) = run_svc_overlap(flow, ServiceConfig::default(), false, 33);
        assert_service_clean(&eng, &io);
        assert_eq!(fa.advised, 2, "budget 1 per session, 2 sessions");
        assert_eq!(fa.migrations, 2);
        assert!(fa.suppressed > 0, "over-budget wants-move must be counted, not advised");
        // The advised consumers still cut some cross-PE traffic.
        assert!(fa.same_pe_piece_bytes > 0);
    }

    /// PR 9 acceptance (tentpole, overlap side): a cap of 1 in-flight
    /// PFS read on one data-plane shard queues the pinned buffers'
    /// demand, opening admission-wait windows on their PEs; with
    /// background workers running, their iterations inside those windows
    /// land in the `ckio.overlap.*` counters (the TASIO measurement).
    /// Without background work the windows still open but measure zero.
    #[test]
    fn governed_waits_overlap_background_work() {
        let governed = ServiceConfig {
            max_inflight_reads: Some(1),
            data_plane_shards: Some(1),
            ..Default::default()
        };
        let (bg, io_a, eng_a) =
            run_svc_overlap(ConsumerPlacement::Static, governed.clone(), true, 37);
        let (nobg, io_b, eng_b) =
            run_svc_overlap(ConsumerPlacement::Static, governed, false, 37);
        assert_service_clean(&eng_a, &io_a);
        assert_service_clean(&eng_b, &io_b);
        assert!(bg.overlap_windows > 0 && nobg.overlap_windows > 0, "cap 1 must queue demand");
        assert!(bg.overlap_bg_iters > 0, "background work must be measured inside waits");
        assert!(bg.overlap_bg_s > 0.0 && bg.overlap_window_s > 0.0);
        assert!(bg.bg_total_iters >= bg.overlap_bg_iters);
        assert_eq!(nobg.overlap_bg_iters, 0);
        assert_eq!(nobg.bg_total_iters, 0);
        // The flushed metrics agree with the engine-core totals.
        let m = &eng_a.core.metrics;
        assert_eq!(m.counter(keys::OVERLAP_WINDOWS), bg.overlap_windows);
        assert_eq!(m.counter(keys::OVERLAP_BG_ITERS), bg.overlap_bg_iters);
    }

    /// PR 9 acceptance: deterministic mid-migration session close. With
    /// the flow threshold equal to the round count, each consumer's
    /// single flow report fires off its *final* piece, so the advice —
    /// and the migration it triggers — races the leader's session close
    /// inside one run. Whether each advice lands before or after
    /// teardown, nothing leaks: flow matrices, flow accounts,
    /// first-served marks, wait windows, and forwarded envelopes are
    /// all gone at quiescence.
    #[test]
    fn mid_migration_session_close_tears_down_clean() {
        let rounds = OVERLAP_SHAPE.4;
        let flow = ConsumerPlacement::FlowAware { piece_threshold: rounds, migration_budget: 4 };
        let (fa, io, eng) = run_svc_overlap(flow, ServiceConfig::default(), false, 47);
        assert!(fa.flow_reports > 0, "the final pieces must still report");
        assert!(fa.advised <= 4);
        assert_service_clean(&eng, &io);
    }

    /// PR 9 satellite (regression, first_served drop cleanup): a traced
    /// run populates the assembler's per-session first-served marks (the
    /// `session/first_byte` instants prove it), and closing every
    /// session clears them on every PE.
    #[test]
    fn session_drop_clears_first_served_marks() {
        use crate::trace::{self, names, TraceConfig};
        trace::arm(TraceConfig::on());
        let (st, io, eng) =
            run_svc_overlap(ConsumerPlacement::Static, ServiceConfig::default(), false, 61);
        assert!(eng.core.trace.is_enabled(), "armed station must install a sink at boot");
        assert!(st.cross_pe_piece_bytes > 0);
        for pe in 0..eng.core.topo.npes() {
            let asm: &crate::ckio::assembler::ReadAssembler =
                eng.chare(ChareRef::new(io.assemblers, pe));
            assert_eq!(asm.first_served_count(), 0, "first-served marks leaked on PE {pe}");
        }
        assert_service_clean(&eng, &io);
        drop(eng);
        let sinks = trace::collect();
        trace::disarm();
        let json = trace::export_chrome(&sinks);
        assert!(
            json.contains(names::SESSION_FIRST_BYTE),
            "marks were really set while the sessions ran"
        );
    }

    /// PR 9 anchor: `BENCH_pr9.json` is valid JSON and carries the
    /// consumer-locality and overlap sections with the observability
    /// keys the CI bench smoke greps for.
    #[test]
    fn bench_pr9_json_is_wellformed() {
        let j = bench_pr9_json(1);
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"bench\":\"svc_overlap\""));
        assert!(j.contains("\"pr\":9"));
        for key in [
            "\"consumer_locality\"",
            "\"overlap\"",
            "\"static\"",
            "\"flow_aware\"",
            "\"with_bg\"",
            "\"without_bg\"",
            "cross_pe_reduction",
            "ckio.place.piece_same_pe",
            "ckio.place.piece_cross_pe",
            "ckio.consumer.flow_reports",
            "ckio.consumer.migrations_advised",
            "ckio.consumer.advice_suppressed",
            "amt.migrations",
            "ckio.overlap.windows",
            "ckio.overlap.bg_iters",
            "ckio.overlap.bg_time",
            "ckio.overlap.window_time",
            "bg_total_iters",
        ] {
            assert!(j.contains(key), "missing {key} in BENCH_pr9 json");
        }
    }

    // ---- svc_rw (PR 10): collective output plane ----

    #[test]
    fn svc_rw_read_after_write_is_resident_and_verified() {
        let (nodes, pes, size, clients, piece) = RW_SHAPE;
        let (st, io, eng) = run_svc_rw(
            nodes,
            pes,
            size,
            clients,
            piece,
            ServiceConfig::default(),
            FileOptions::with_readers(4),
            WriteOptions::default(),
            true,
            true,
            0.0,
            7,
        );
        assert_service_clean(&eng, &io);
        // The headline: the read session over the just-written range
        // never touches the PFS (byte identity is asserted inside the
        // RwClient read path against the file pattern).
        assert_eq!(st.rw_pfs_read_bytes, 0);
        assert!(st.store_hit_bytes > 0, "read-back must be charged as store hits");
        // Eager mode: the flush barrier drained everything durably.
        assert_eq!(st.outcome.written_bytes, size);
        assert_eq!(st.outcome.dirty_bytes, 0);
        assert_eq!(st.pfs_bytes_written, size);
        assert_eq!(st.degraded_bytes, 0);
        // Aggregation: stripe-coalesced extents, not per-piece RPCs.
        let (naive_rpcs, naive_bytes, _, _) =
            run_naive_write(nodes, pes, size, clients, piece, 7);
        assert_eq!(naive_bytes, size);
        assert!(
            st.pfs_write_rpcs as f64 * 4.0 <= naive_rpcs as f64,
            "want >= 4x write-op reduction: ckio {} vs naive {}",
            st.pfs_write_rpcs,
            naive_rpcs
        );
    }

    #[test]
    fn svc_rw_lazy_close_parks_dirty_and_purge_forces_writeback() {
        let (nodes, pes, size, clients, piece) = RW_SHAPE;
        let (st, io, eng) = run_svc_rw(
            nodes,
            pes,
            size,
            clients,
            piece,
            ServiceConfig::default(),
            FileOptions::with_readers(4),
            WriteOptions::lazy(),
            false,
            true,
            0.0,
            8,
        );
        assert_service_clean(&eng, &io);
        // Lazy close parked every byte dirty — nothing durable yet at
        // close, read-back still fully resident.
        assert_eq!(st.outcome.dirty_bytes, size);
        assert_eq!(st.outcome.written_bytes, 0);
        assert_eq!(st.rw_pfs_read_bytes, 0);
        // The file close purged the parked array: the store forced a
        // writeback of every dirty span before dropping it, so the data
        // still reached the PFS exactly once.
        assert!(st.dirty_writebacks > 0);
        assert_eq!(st.dirty_writeback_bytes, size);
        assert_eq!(st.pfs_bytes_written, size);
    }

    #[test]
    fn svc_rw_chaos_flush_barrier_and_exactly_once_close() {
        let (nodes, pes, size, clients, piece) = RW_SHAPE;
        // Small stripes → many write RPCs → transient faults at p=0.2
        // are certain to hit; the retry plane must clear all of them.
        let cfg = ServiceConfig {
            max_inflight_reads: Some(4),
            data_plane_shards: Some(1),
            retry: Some(RetryPolicy::default()),
            ..Default::default()
        };
        let wopts = WriteOptions { stripe_bytes: 64 << 10, ..Default::default() };
        let (st, io, eng) = run_svc_rw(
            nodes,
            pes,
            size,
            clients,
            piece,
            cfg,
            FileOptions::with_readers(4),
            wopts,
            true,
            false,
            0.2,
            9,
        );
        // run_svc_rw already asserts the outcome future fired exactly
        // once (the exactly-once close callback); the barrier means
        // every byte is durable despite the injected faults.
        assert_service_clean(&eng, &io);
        assert_eq!(st.outcome.written_bytes, size);
        assert_eq!(st.degraded_bytes, 0);
        assert!(st.retries > 0, "p=0.2 over ~128 write RPCs must retry at least once");
        assert_eq!(st.pfs_bytes_written, size, "retries must not double-count durable bytes");
    }

    #[test]
    fn svc_rw_table_renders() {
        let t = svc_rw(1);
        let s = t.render();
        assert!(s.contains("naive") && s.contains("ckio_lazy"));
    }

    #[test]
    fn bench_pr10_json_is_wellformed() {
        let j = bench_pr10_json(1);
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"bench\":\"svc_rw\""));
        assert!(j.contains("\"pr\":10"));
        // The residency headline must be an exact zero in the JSON.
        assert!(j.contains("\"rw_pfs_read_bytes\":0"));
        for key in [
            "\"write\"",
            "\"naive\"",
            "\"aggregated\"",
            "write_op_reduction",
            "\"read_after_write\"",
            "\"lazy_writeback\"",
            "\"write_chaos\"",
            "pfs.write_rpcs",
            "pfs.bytes_written",
            "ckio.write.puts",
            "ckio.write.extents_flushed",
            "ckio.write.flushes",
            "ckio.write.degraded_bytes",
            "ckio.store.dirty_writebacks",
            "ckio.store.dirty_writeback_bytes",
            "ckio.store.hit_bytes",
            "ckio.retry.attempts",
        ] {
            assert!(j.contains(key), "missing {key} in BENCH_pr10 json");
        }
    }
}
