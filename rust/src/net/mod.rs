//! Interconnect model.
//!
//! Models a Bridges2-like fabric (Mellanox HDR-200: ~25 GB/s per NIC,
//! ~2 µs MPI-level latency). The paper's key premise (Fig. 2) is that
//! moving bytes node-to-node is ~6× faster than reading them from the
//! parallel file system — CkIO exploits exactly that gap, so this model
//! is what makes the reproduction's trade-offs meaningful.
//!
//! Structure: per-message delay = base latency (placement-dependent) +
//! serialization over the *sending node's NIC*, which is a shared FIFO
//! resource (`free_at` horizon per node). Intra-node messages move at
//! memory bandwidth; same-PE messages are scheduler-only. Zero-copy
//! transfers (CkIO's buffer→assembler path) skip one copy charge, which
//! we model as a reduced per-byte cost.

use crate::amt::time::{from_micros, Time};
use crate::amt::topology::{NodeId, Pe, Topology};
use crate::metrics::Metrics;

/// Network model parameters.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// One-way small-message latency across nodes.
    pub remote_latency: Time,
    /// One-way small-message latency within a node (shared memory).
    pub local_latency: Time,
    /// NIC bandwidth, bytes/sec (HDR-200 ≈ 25 GB/s).
    pub nic_bw: f64,
    /// Intra-node memory-copy bandwidth, bytes/sec.
    pub mem_bw: f64,
    /// Multiplier applied to per-byte costs for zero-copy transfers
    /// (RDMA get: the payload still crosses the wire but skips the
    /// packing copy on both sides).
    pub zerocopy_factor: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            remote_latency: from_micros(2.0),
            local_latency: from_micros(0.3),
            nic_bw: 25e9,
            mem_bw: 80e9,
            zerocopy_factor: 0.75,
        }
    }
}

/// Delivery class, for accounting and cost selection.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Transfer {
    /// Eagerly marshalled message (control traffic, small payloads).
    Eager,
    /// Zero-copy bulk transfer (CkIO data plane, Charm++ ZC API).
    ZeroCopy,
}

/// Messages at or below this size use the control lane (no NIC-FIFO
/// queueing behind bulk transfers).
pub const SMALL_MSG_LANE_BYTES: u64 = 64 << 10;

/// The interconnect: per-node NIC horizons + cost model.
#[derive(Debug)]
pub struct Network {
    pub cfg: NetConfig,
    /// Per-node transmit horizon: the NIC serializes outgoing payloads.
    tx_free_at: Vec<Time>,
    /// Total bytes charged (flushed into metrics at quiescence — a
    /// per-message BTreeMap hit was measurable on the hot path).
    pub total_bytes: u64,
    /// Total NIC serialization time accumulated.
    pub total_busy: Time,
}

impl Network {
    pub fn new(cfg: NetConfig, topo: &Topology) -> Network {
        Network { cfg, tx_free_at: vec![0; topo.nodes as usize], total_bytes: 0, total_busy: 0 }
    }

    /// Delay for a message of `bytes` from `from` to `to`, submitted at
    /// `now`. Mutates the sending NIC's horizon (congestion) for
    /// cross-node transfers.
    pub fn delay(
        &mut self,
        topo: &Topology,
        metrics: &mut Metrics,
        now: Time,
        from: Pe,
        to: Pe,
        bytes: u64,
        class: Transfer,
    ) -> Time {
        let _ = &metrics;
        self.total_bytes += bytes;
        if from == to {
            // Same PE: no wire, scheduler cost only.
            return 0;
        }
        let per_byte_factor = match class {
            Transfer::Eager => 1.0,
            Transfer::ZeroCopy => self.cfg.zerocopy_factor,
        };
        if topo.same_node(from, to) {
            let ser = (bytes as f64 / self.cfg.mem_bw * 1e9 * per_byte_factor) as Time;
            return self.cfg.local_latency + ser;
        }
        let node = topo.node_of(from).0 as usize;
        let ser = (bytes as f64 / self.cfg.nic_bw * 1e9 * per_byte_factor) as Time;
        // Small (control) messages travel on their own virtual lane and
        // do not head-of-line block behind bulk transfers — HDR fabrics
        // and Charm++'s eager path both provide this. Only bulk payloads
        // contend for the NIC's serialization horizon.
        if bytes <= SMALL_MSG_LANE_BYTES {
            return self.cfg.remote_latency + ser;
        }
        let start = self.tx_free_at[node].max(now);
        let done_tx = start + ser;
        self.tx_free_at[node] = done_tx;
        self.total_busy += ser;
        (done_tx - now) + self.cfg.remote_latency
    }

    /// Pure transfer-time estimate (no queueing side effects) — used by
    /// Fig. 2's "send the same bytes over the network" measurement.
    pub fn transfer_time(&self, topo: &Topology, from: Pe, to: Pe, bytes: u64) -> Time {
        if from == to {
            return 0;
        }
        if topo.same_node(from, to) {
            self.cfg.local_latency + (bytes as f64 / self.cfg.mem_bw * 1e9) as Time
        } else {
            self.cfg.remote_latency + (bytes as f64 / self.cfg.nic_bw * 1e9) as Time
        }
    }

    /// NIC horizon for a node (test/inspection).
    pub fn tx_horizon(&self, node: NodeId) -> Time {
        self.tx_free_at[node.0 as usize]
    }

    /// Reset congestion state (between benchmark repetitions).
    pub fn reset(&mut self) {
        self.tx_free_at.iter_mut().for_each(|t| *t = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Network, Topology, Metrics) {
        (
            Network::new(NetConfig::default(), &Topology::new(2, 4)),
            Topology::new(2, 4),
            Metrics::new(),
        )
    }

    #[test]
    fn same_pe_is_free() {
        let (mut net, topo, mut m) = setup();
        assert_eq!(net.delay(&topo, &mut m, 0, Pe(0), Pe(0), 1 << 20, Transfer::Eager), 0);
    }

    #[test]
    fn intra_node_faster_than_cross_node() {
        let (mut net, topo, mut m) = setup();
        let local = net.delay(&topo, &mut m, 0, Pe(0), Pe(1), 64 << 20, Transfer::Eager);
        net.reset();
        let remote = net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 64 << 20, Transfer::Eager);
        assert!(local < remote, "local={local} remote={remote}");
    }

    #[test]
    fn nic_serializes_concurrent_sends() {
        let (mut net, topo, mut m) = setup();
        let d1 = net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 256 << 20, Transfer::Eager);
        let d2 = net.delay(&topo, &mut m, 0, Pe(1), Pe(5), 256 << 20, Transfer::Eager);
        // Second send queues behind the first on node 0's NIC.
        assert!(d2 > d1, "d1={d1} d2={d2}");
        assert!(d2 as f64 > 1.9 * d1 as f64);
    }

    #[test]
    fn different_nodes_dont_contend() {
        let (mut net, topo, mut m) = setup();
        let d1 = net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 256 << 20, Transfer::Eager);
        let d2 = net.delay(&topo, &mut m, 0, Pe(4), Pe(0), 256 << 20, Transfer::Eager);
        assert_eq!(d1, d2);
    }

    #[test]
    fn zerocopy_cheaper_than_eager() {
        let (mut net, topo, mut m) = setup();
        let eager = net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 64 << 20, Transfer::Eager);
        net.reset();
        let zc = net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 64 << 20, Transfer::ZeroCopy);
        assert!(zc < eager);
    }

    #[test]
    fn hdr200_rate_sanity() {
        // 1 GiB across nodes at 25 GB/s ≈ 43 ms.
        let (net, topo, _) = setup();
        let t = net.transfer_time(&topo, Pe(0), Pe(4), 1 << 30);
        let secs = t as f64 / 1e9;
        assert!((secs - (1u64 << 30) as f64 / 25e9).abs() < 1e-3, "secs={secs}");
    }

    #[test]
    fn metrics_charged_for_bulk() {
        let (mut net, topo, mut m) = setup();
        net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 1 << 20, Transfer::Eager);
        assert_eq!(net.total_bytes, 1 << 20);
        assert!(net.total_busy > 0);
    }

    #[test]
    fn control_lane_skips_nic_queue() {
        let (mut net, topo, mut m) = setup();
        // A bulk transfer occupies node 0's NIC...
        let bulk = net.delay(&topo, &mut m, 0, Pe(0), Pe(4), 256 << 20, Transfer::Eager);
        assert!(bulk > 0);
        // ...but a control message from the same node is not delayed
        // behind it (separate virtual lane).
        let ctl = net.delay(&topo, &mut m, 0, Pe(1), Pe(5), 256, Transfer::Eager);
        assert!(ctl < 10_000, "control message HOL-blocked: {ctl}ns");
    }
}
