//! Instrumentation: counters, accumulated durations, and report tables.
//!
//! Every subsystem (engine, PFS, network, CkIO, apps) charges into one
//! [`Metrics`] sink; experiment drivers read it back to produce the
//! paper's breakdowns (e.g. §V: I/O vs. data-permutation vs.
//! over-decomposition overhead, and the background-work fractions of
//! Figs. 8–9).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::amt::time::{self, Time};

pub mod histogram;
pub use histogram::Histogram;

/// A metrics sink: named counters, named duration accumulators, raw
/// values, and latency histograms.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    durations: BTreeMap<&'static str, Time>,
    values: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, Histogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn count(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    /// Accumulate a duration.
    pub fn charge(&mut self, name: &'static str, d: Time) {
        *self.durations.entry(name).or_insert(0) += d;
    }

    /// Record/overwrite a raw value (gauges, final ratios).
    pub fn set(&mut self, name: &'static str, v: f64) {
        self.values.insert(name, v);
    }

    /// Add to a raw value.
    pub fn add(&mut self, name: &'static str, v: f64) {
        *self.values.entry(name).or_insert(0.0) += v;
    }

    /// Keep the maximum of a raw value (e.g. "last I/O completion time").
    pub fn set_max(&mut self, name: &'static str, v: f64) {
        let e = self.values.entry(name).or_insert(f64::MIN);
        if v > *e {
            *e = v;
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn duration(&self, name: &str) -> Time {
        self.durations.get(name).copied().unwrap_or(0)
    }

    pub fn duration_secs(&self, name: &str) -> f64 {
        time::to_secs(self.duration(name))
    }

    pub fn value(&self, name: &str) -> f64 {
        self.values.get(name).copied().unwrap_or(0.0)
    }

    /// Record one latency sample (nanoseconds) into a named histogram.
    pub fn record(&mut self, name: &'static str, v: u64) {
        self.histograms.entry(name).or_default().record(v);
    }

    /// The named histogram, if any samples were recorded.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// A quantile of the named histogram in nanoseconds (0 when no
    /// samples were recorded).
    pub fn quantile(&self, name: &str, q: f64) -> u64 {
        self.histograms.get(name).map_or(0, |h| h.quantile(q))
    }

    /// Merge another sink into this one (e.g. per-run → aggregate).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.durations {
            *self.durations.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.values {
            *self.values.entry(k).or_insert(0.0) += v;
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k).or_default().merge(h);
        }
    }

    /// Reset everything.
    pub fn clear(&mut self) {
        self.counters.clear();
        self.durations.clear();
        self.values.clear();
        self.histograms.clear();
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            let _ = writeln!(out, "counters:");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "  {k:40} {v}");
            }
        }
        if !self.durations.is_empty() {
            let _ = writeln!(out, "durations:");
            for (k, v) in &self.durations {
                let _ = writeln!(out, "  {k:40} {}", time::human(*v));
            }
        }
        if !self.values.is_empty() {
            let _ = writeln!(out, "values:");
            for (k, v) in &self.values {
                let _ = writeln!(out, "  {k:40} {v:.6}");
            }
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(out, "latency histograms:");
            for (k, h) in &self.histograms {
                let _ = writeln!(
                    out,
                    "  {k:40} n={} p50={} p99={} p99.9={} max={}",
                    h.count(),
                    time::human(h.quantile(0.5)),
                    time::human(h.quantile(0.99)),
                    time::human(h.quantile(0.999)),
                    time::human(h.max()),
                );
            }
        }
        out
    }
}

/// Well-known metric names, so subsystems and reports agree.
pub mod keys {
    /// Tasks executed by all PE schedulers.
    pub const TASKS: &str = "amt.tasks";
    /// Messages sent (all kinds).
    pub const MSGS: &str = "amt.msgs_sent";
    /// Location-manager forwarding hops (stale caches / in-flight chares).
    pub const FWD_HOPS: &str = "amt.forward_hops";
    /// Chare migrations completed.
    pub const MIGRATIONS: &str = "amt.migrations";
    /// Bytes moved over the interconnect (modeled).
    pub const NET_BYTES: &str = "net.bytes";
    /// Time the interconnect spent serializing data (modeled, summed).
    pub const NET_BUSY: &str = "net.busy";
    /// PFS read RPCs issued.
    pub const PFS_RPCS: &str = "pfs.rpcs";
    /// Bytes read from the PFS.
    pub const PFS_BYTES: &str = "pfs.bytes_read";
    /// Aggregate time OSTs spent servicing requests.
    pub const OST_BUSY: &str = "pfs.ost_busy";
    /// High-water mark of reads simultaneously in flight at the PFS
    /// (gauge; the admission governor's cap is asserted against this).
    pub const PFS_MAX_CONCURRENT: &str = "pfs.max_concurrent_reads";
    /// CkIO: read requests served to clients.
    pub const CKIO_READS: &str = "ckio.reads_served";
    /// CkIO: bytes delivered to clients.
    pub const CKIO_BYTES: &str = "ckio.bytes_delivered";
    /// CkIO: read sessions started.
    pub const SESSIONS: &str = "ckio.sessions";
    /// CkIO: session starts rejected with a structured error.
    pub const SESSIONS_REJECTED: &str = "ckio.sessions_rejected";
    /// CkIO: opens rejected with a structured error (invalid or
    /// conflicting options).
    pub const OPENS_REJECTED: &str = "ckio.opens_rejected";
    /// CkIO: re-opens of an already-open file (refcount sharing).
    pub const REOPENS: &str = "ckio.reopens";
    /// CkIO: duplicate session/file closes observed (idempotent).
    pub const DOUBLE_CLOSE: &str = "ckio.double_close";
    /// Manager: reads answered with a modeled NACK because their session
    /// was already torn down.
    pub const READS_AFTER_CLOSE: &str = "ckio.reads_after_close";
    /// Assembler: accumulated client-read assembly latency.
    pub const ASSEMBLY_LATENCY: &str = "ckio.assembly_latency";
    /// Assembler: late pieces of a closed session, tolerated and dropped.
    pub const PIECES_AFTER_CLOSE: &str = "ckio.pieces_after_close";
    /// Buffer chares: pieces served to assemblers from resident data.
    pub const PIECES_SERVED: &str = "ckio.pieces_served";
    /// Buffer chares: fetches answered with a modeled NACK chunk
    /// (teardown drain).
    pub const PIECES_NACKED: &str = "ckio.pieces_nacked";
    /// Buffer chares: fetch requests received.
    pub const FETCHES: &str = "ckio.fetches";
    /// Buffer chares: fetches arriving after the buffer dropped.
    pub const FETCH_AFTER_DROP: &str = "ckio.fetch_after_drop";
    /// Completion time of the last prefetch I/O (gauge, ns; §V's io_s).
    pub const LAST_IO_NS: &str = "ckio.last_io_ns";
    /// Buffer chares rebound to a parked array instead of reading.
    pub const BUFFERS_REBOUND: &str = "ckio.buffers_rebound";
    /// Sessions that reused a parked buffer array wholesale.
    pub const BUFFER_REUSE: &str = "ckio.buffer_reuse";
    /// Parked arrays evicted to stay under the store budget.
    pub const BUFFER_CACHE_EVICTIONS: &str = "ckio.buffer_cache_evictions";
    /// Span store: peer-fetch bytes served from a resident slot.
    pub const STORE_PEER_SERVED: &str = "ckio.store.peer_served";
    /// Span store: peer fetches that missed (source gone meanwhile).
    pub const STORE_PEER_MISS: &str = "ckio.store.peer_miss";
    /// Span store: bytes served from resident data (peer-fetched slots
    /// and exact-match rebinds) instead of new PFS reads.
    pub const STORE_HIT: &str = "ckio.store.hit_bytes";
    /// Span store: bytes for which a PFS read was actually issued.
    pub const STORE_MISS: &str = "ckio.store.miss_bytes";
    /// Span store: resident bytes released by budget eviction or
    /// file-close purge.
    pub const STORE_EVICTED: &str = "ckio.store.evicted_bytes";
    /// Span store: bytes currently resident in parked arrays (gauge;
    /// maintained as add-deltas by each data-plane shard so the value is
    /// the *sum* across shards, never a single shard's view).
    pub const STORE_RESIDENT: &str = "ckio.store.resident_bytes";
    /// Admission governor: PFS reads deferred because the per-shard
    /// in-flight cap was reached.
    pub const GOV_THROTTLED: &str = "ckio.governor.throttled";
    /// Admission governor: the in-flight cap (gauge; the *sum* of
    /// per-shard caps over the active shards — the service-wide
    /// admission ceiling, and exactly the cap itself when one shard is
    /// active. Since PR 5 configuration happens once at boot
    /// (`ServiceConfig`), which publishes the initial sum; after that
    /// only the AIMD feedback loop can move a shard's cap, as
    /// add-deltas).
    pub const GOV_CAP: &str = "ckio.governor.cap";
    /// Admission governor: cap changes made by the adaptive (AIMD)
    /// feedback loop.
    pub const GOV_ADAPTATIONS: &str = "ckio.governor.adaptations";
    /// Admission governor (PR 5): tickets admitted under the
    /// Interactive QoS class (immediate grants and weighted dequeues
    /// alike; with `GOV_GRANTED_BULK`/`GOV_GRANTED_SCAVENGER` this is
    /// the observable the weighted-fair dequeue ratios show up on).
    pub const GOV_GRANTED_INTERACTIVE: &str = "ckio.governor.class_granted.interactive";
    /// Admission governor (PR 5): tickets admitted under the Bulk class.
    pub const GOV_GRANTED_BULK: &str = "ckio.governor.class_granted.bulk";
    /// Admission governor (PR 5): tickets admitted under the Scavenger
    /// class.
    pub const GOV_GRANTED_SCAVENGER: &str = "ckio.governor.class_granted.scavenger";
    /// Store-aware placement (PR 4): buffer chares whose PE was chosen
    /// by a shard's `PlacementPlan` (dominant peer source) rather than
    /// the fallback policy.
    pub const PLACE_PLANNED: &str = "ckio.place.planned";
    /// Peer-fetched bytes that stayed on one PE (requester and source
    /// colocated — what store-aware placement maximizes).
    pub const PLACE_SAME_PE: &str = "ckio.place.same_pe_fetch";
    /// Peer-fetched bytes that crossed PEs (the Fig. 12 cost store-aware
    /// placement collapses toward zero).
    pub const PLACE_CROSS_PE: &str = "ckio.place.cross_pe_fetch";
    /// Store-aware placement: buffers whose registration found less
    /// peer coverage than their plan promised (a claim owner unclaimed
    /// between `EP_SHARD_PLAN` and `EP_SHARD_REGISTER`; the shortfall
    /// degrades gracefully to PFS reads).
    pub const PLACE_DEGRADED: &str = "ckio.place.degraded";
    /// Data-plane shards: most messages processed by any one shard
    /// (gauge, set by the harness post-run; with `msgs_mean` this is the
    /// shard-imbalance pair).
    pub const SHARD_MSGS_MAX: &str = "ckio.shard.msgs_max";
    /// Data-plane shards: mean messages processed per *active* shard
    /// (gauge, set by the harness post-run).
    pub const SHARD_MSGS_MEAN: &str = "ckio.shard.msgs_mean";
    /// Background-work time accumulated by compute chares (Figs. 8–9).
    pub const BG_WORK: &str = "app.bg_work";
    /// Flight recorder: events evicted from the bounded trace ring by
    /// the drop-oldest policy (only emitted while tracing is enabled —
    /// truncation is never silent).
    pub const TRACE_DROPPED: &str = "ckio.trace.dropped";
    /// Histogram: session makespan, start accepted → close
    /// acknowledged at the director (ns).
    pub const LATENCY_SESSION_MAKESPAN: &str = "ckio.latency.session_makespan";
    /// Histogram: admission wait of Interactive-class tickets,
    /// governor enqueue → grant (ns; immediate grants record 0).
    pub const LATENCY_ADMISSION_WAIT_INTERACTIVE: &str = "ckio.latency.admission_wait.interactive";
    /// Histogram: admission wait of Bulk-class tickets (ns).
    pub const LATENCY_ADMISSION_WAIT_BULK: &str = "ckio.latency.admission_wait.bulk";
    /// Histogram: admission wait of Scavenger-class tickets (ns).
    pub const LATENCY_ADMISSION_WAIT_SCAVENGER: &str = "ckio.latency.admission_wait.scavenger";
    /// Histogram: PFS read RPC service time, issue → complete (ns).
    pub const LATENCY_PFS_READ: &str = "ckio.latency.pfs_read_service";
    /// Histogram: client-read assembly latency, request → last piece
    /// (ns; the per-sample distribution behind `ASSEMBLY_LATENCY`).
    pub const LATENCY_ASSEMBLY: &str = "ckio.latency.assembly";
    /// Histogram: peer-fetch round trip, request sent → chunk received
    /// at the requesting buffer (ns; successful fetches only).
    pub const LATENCY_PEER_FETCH: &str = "ckio.latency.peer_fetch";
    /// Fault injection (PR 8): PFS reads that completed with a
    /// transient error (retryable; the same extent may succeed next
    /// attempt).
    pub const FAULT_TRANSIENT: &str = "ckio.fault.transient";
    /// Fault injection: PFS reads that completed with a persistent
    /// error (the extent deterministically re-fails every attempt).
    pub const FAULT_PERSISTENT: &str = "ckio.fault.persistent";
    /// Fault injection: PFS reads that returned fewer valid bytes than
    /// requested (short reads; treated as failures by the retry plane).
    pub const FAULT_SHORT: &str = "ckio.fault.short_reads";
    /// Fault injection: PFS reads whose service time was stretched by a
    /// straggler OST's multiplier.
    pub const FAULT_STRAGGLER: &str = "ckio.fault.straggler_rpcs";
    /// Reliability plane (PR 8): PFS read re-issues — every attempt
    /// beyond an extent's first (hedges counted separately).
    pub const RETRY_ATTEMPTS: &str = "ckio.retry.attempts";
    /// Reliability plane: read deadlines that expired at the buffer
    /// (each either abandons the attempt or arms a hedge).
    pub const RETRY_TIMEOUTS: &str = "ckio.retry.timeouts";
    /// Reliability plane: hedged duplicate reads issued past their
    /// deadline while the original stayed in flight.
    pub const RETRY_HEDGES: &str = "ckio.retry.hedges";
    /// Reliability plane: completions of attempts already abandoned by
    /// their deadline (dropped; the ticket was returned at abandonment).
    pub const RETRY_LATE: &str = "ckio.retry.late_completions";
    /// Reliability plane: extents abandoned after the retry budget was
    /// exhausted (each degrades its slot to a modeled chunk).
    pub const RETRY_GAVE_UP: &str = "ckio.retry.gave_up";
    /// Bytes of client reads answered from degraded (NACK / gave-up)
    /// slots — the per-session split rides the close callback's
    /// `SessionOutcome`.
    pub const SESSION_DEGRADED: &str = "ckio.session.degraded_bytes";
    /// Admission governor: tickets and queued demand reclaimed from
    /// torn-down owners (drop-time bulk return; without it a dead
    /// buffer's in-flight reads would leak cap forever).
    pub const GOV_RECLAIMED: &str = "ckio.governor.reclaimed";
    /// Consumer locality (PR 9): piece bytes delivered by an assembler
    /// from a buffer on its *own* PE — the buffer→assembler delivery
    /// leg, the counterpart of `ckio.place.same_pe_fetch` (which only
    /// covers the buffer↔buffer peer-fetch leg).
    pub const PLACE_PIECE_SAME_PE: &str = "ckio.place.piece_same_pe";
    /// Consumer locality (PR 9): piece bytes delivered from a buffer on
    /// a *different* PE — what FlowAware consumer migration shrinks.
    pub const PLACE_PIECE_CROSS_PE: &str = "ckio.place.piece_cross_pe";
    /// Consumer locality (PR 9): assembler flow-report deltas received
    /// by the director (FlowAware sessions only).
    pub const CONSUMER_FLOW_REPORTS: &str = "ckio.consumer.flow_reports";
    /// Consumer locality (PR 9): migrations the director advised (each
    /// decrements the session's budget; hysteresis and budget caps are
    /// counted on `ckio.consumer.advice_suppressed`).
    pub const CONSUMER_MIGRATIONS_ADVISED: &str = "ckio.consumer.migrations_advised";
    /// Consumer locality (PR 9): advice the flow matrix justified but
    /// the advisor withheld — budget exhausted, or the destination was
    /// already in the consumer's hysteresis set.
    pub const CONSUMER_ADVICE_SUPPRESSED: &str = "ckio.consumer.advice_suppressed";
    /// I/O-aware overlap (PR 9): admission-wait overlap windows closed
    /// (a window spans first queued ticket → demand drained on a PE).
    pub const OVERLAP_WINDOWS: &str = "ckio.overlap.windows";
    /// I/O-aware overlap (PR 9): background-chare tasks run inside open
    /// overlap windows — iterations that fit inside input time (TASIO).
    pub const OVERLAP_BG_ITERS: &str = "ckio.overlap.bg_iters";
    /// I/O-aware overlap (PR 9): background-chare execution time inside
    /// overlap windows.
    pub const OVERLAP_BG_TIME: &str = "ckio.overlap.bg_time";
    /// I/O-aware overlap (PR 9): total wall span of closed overlap
    /// windows (the denominator of the overlap-efficiency ratio).
    pub const OVERLAP_WINDOW_TIME: &str = "ckio.overlap.window_time";
    /// PFS write RPCs issued (PR 10) — the aggregated-vs-naive write
    /// reduction's numerator/denominator pair with the producer piece
    /// count.
    pub const PFS_WRITE_RPCS: &str = "pfs.write_rpcs";
    /// Bytes written to the PFS (PR 10).
    pub const PFS_BYTES_WRITTEN: &str = "pfs.bytes_written";
    /// Histogram: PFS write RPC service time, issue -> commit (ns;
    /// PR 10 — feeds the same per-shard AIMD loop as reads).
    pub const LATENCY_PFS_WRITE: &str = "ckio.latency.pfs_write_service";
    /// Write plane (PR 10): producer put calls completed (every piece
    /// accepted by a write buffer and acknowledged back).
    pub const WRITE_PUTS: &str = "ckio.write.puts";
    /// Write plane: bytes accepted from producers into write buffers.
    pub const WRITE_BYTES: &str = "ckio.write.bytes_accepted";
    /// Write plane: write sessions started.
    pub const WRITE_SESSIONS: &str = "ckio.write.sessions";
    /// Write plane: flush barriers completed (every dirty extent durable
    /// or degraded before the flush callback fired).
    pub const WRITE_FLUSHES: &str = "ckio.write.flushes";
    /// Write plane: stripe-aligned extents flushed to the PFS (each one
    /// governed write op — compare against producer pieces for the
    /// collective-buffering reduction).
    pub const WRITE_EXTENTS: &str = "ckio.write.extents_flushed";
    /// Write plane: dirty bytes abandoned after the write retry budget
    /// (degraded into the session outcome, never silently dropped).
    pub const WRITE_DEGRADED: &str = "ckio.write.degraded_bytes";
    /// Span store (PR 10): dirty bytes — produced but not yet durable —
    /// held under store claims (gauge; add-deltas per shard like
    /// `STORE_RESIDENT`; quiescence requires 0).
    pub const STORE_DIRTY: &str = "ckio.store.dirty_bytes";
    /// Span store: LRU evictions of a dirty parked span, each forcing a
    /// writeback before the bytes may be dropped.
    pub const STORE_DIRTY_WRITEBACKS: &str = "ckio.store.dirty_writebacks";
    /// Span store: bytes flushed to the PFS by eviction-forced
    /// writebacks (durable or degraded; never silently discarded).
    pub const STORE_DIRTY_WRITEBACK_BYTES: &str = "ckio.store.dirty_writeback_bytes";

    /// The observability catalog: `(key, kind, emitting module, what it
    /// measures)` for every constant above — the registry behind
    /// `ckio lint --dump-metrics` and `docs/OBSERVABILITY.md`. Rows
    /// reference the constants (a renamed key cannot strand a stale
    /// row), and `catalog_covers_every_key` below fails the build the
    /// moment a new key is declared without a catalog entry.
    pub fn catalog() -> Vec<(&'static str, &'static str, &'static str, &'static str)> {
        vec![
            (TASKS, "counter", "amt/engine.rs", "tasks executed by all PE schedulers"),
            (MSGS, "counter", "amt/engine.rs", "messages sent (all kinds)"),
            (FWD_HOPS, "counter", "amt/engine.rs", "location-manager forwarding hops"),
            (MIGRATIONS, "counter", "amt/engine.rs", "chare migrations completed"),
            (NET_BYTES, "counter", "amt/engine.rs (reserved)", "bytes moved over the modeled interconnect"),
            (NET_BUSY, "duration", "amt/engine.rs (reserved)", "modeled interconnect serialization time"),
            (PFS_RPCS, "counter", "pfs/model.rs", "PFS read RPCs issued"),
            (PFS_BYTES, "counter", "pfs/model.rs", "bytes read from the PFS"),
            (OST_BUSY, "duration", "pfs/model.rs", "aggregate OST service time"),
            (PFS_MAX_CONCURRENT, "gauge", "pfs/model.rs", "high-water mark of in-flight PFS reads"),
            (CKIO_READS, "counter", "ckio/assembler.rs", "client read requests served"),
            (CKIO_BYTES, "counter", "ckio/assembler.rs", "bytes delivered to clients"),
            (SESSIONS, "counter", "ckio/director.rs", "read sessions started"),
            (SESSIONS_REJECTED, "counter", "ckio/director.rs", "session starts rejected with a structured error"),
            (OPENS_REJECTED, "counter", "ckio/director.rs", "opens rejected (invalid or conflicting options)"),
            (REOPENS, "counter", "ckio/director.rs", "re-opens of an already-open file"),
            (DOUBLE_CLOSE, "counter", "ckio/director.rs", "duplicate session/file closes (idempotent)"),
            (READS_AFTER_CLOSE, "counter", "ckio/manager.rs", "reads NACKed because their session was torn down"),
            (ASSEMBLY_LATENCY, "duration", "ckio/assembler.rs", "accumulated client-read assembly latency"),
            (PIECES_AFTER_CLOSE, "counter", "ckio/assembler.rs", "late pieces of a closed session, tolerated"),
            (PIECES_SERVED, "counter", "ckio/buffer.rs", "pieces served to assemblers from resident data"),
            (PIECES_NACKED, "counter", "ckio/buffer.rs", "fetches answered with a modeled NACK (teardown drain)"),
            (FETCHES, "counter", "ckio/buffer.rs", "fetch requests received by buffer chares"),
            (FETCH_AFTER_DROP, "counter", "ckio/buffer.rs", "fetches arriving after the buffer dropped"),
            (LAST_IO_NS, "gauge", "ckio/buffer.rs", "completion time of the last prefetch I/O (ns)"),
            (BUFFERS_REBOUND, "counter", "ckio/buffer.rs", "buffer chares rebound to a parked array"),
            (BUFFER_REUSE, "counter", "ckio/director.rs", "sessions that reused a parked array wholesale"),
            (BUFFER_CACHE_EVICTIONS, "counter", "ckio/shard.rs", "parked arrays evicted under the store budget"),
            (STORE_PEER_SERVED, "counter", "ckio/buffer.rs", "peer-fetch bytes served from a resident slot"),
            (STORE_PEER_MISS, "counter", "ckio/buffer.rs", "peer fetches that missed (source gone)"),
            (STORE_HIT, "counter", "ckio/buffer.rs", "bytes served from resident data instead of the PFS"),
            (STORE_MISS, "counter", "ckio/buffer.rs", "bytes for which a PFS read was actually issued"),
            (STORE_EVICTED, "counter", "ckio/shard.rs", "resident bytes released by eviction or purge"),
            (STORE_RESIDENT, "gauge", "ckio/shard.rs", "bytes resident in parked arrays (summed over shards)"),
            (GOV_THROTTLED, "counter", "ckio/shard.rs", "PFS reads deferred at the per-shard cap"),
            (GOV_CAP, "gauge", "ckio/shard.rs", "admission cap (sum of per-shard caps)"),
            (GOV_ADAPTATIONS, "counter", "ckio/shard.rs", "cap changes made by the AIMD feedback loop"),
            (GOV_GRANTED_INTERACTIVE, "counter", "ckio/governor.rs", "tickets admitted under the Interactive class"),
            (GOV_GRANTED_BULK, "counter", "ckio/governor.rs", "tickets admitted under the Bulk class"),
            (GOV_GRANTED_SCAVENGER, "counter", "ckio/governor.rs", "tickets admitted under the Scavenger class"),
            (PLACE_PLANNED, "counter", "ckio/director.rs", "buffers placed by a shard's PlacementPlan"),
            (PLACE_SAME_PE, "counter", "ckio/buffer.rs", "peer-fetched bytes that stayed on one PE"),
            (PLACE_CROSS_PE, "counter", "ckio/buffer.rs", "peer-fetched bytes that crossed PEs"),
            (PLACE_DEGRADED, "counter", "ckio/buffer.rs", "planned buffers that found less coverage than promised"),
            (SHARD_MSGS_MAX, "gauge", "harness/experiments.rs", "most messages processed by any one shard"),
            (SHARD_MSGS_MEAN, "gauge", "harness/experiments.rs", "mean messages per active shard"),
            (BG_WORK, "duration", "harness/bgwork.rs", "background-work time of compute chares"),
            (TRACE_DROPPED, "counter", "amt/engine.rs", "events evicted from the bounded trace ring"),
            (LATENCY_SESSION_MAKESPAN, "histogram", "ckio/director.rs", "session makespan, start accepted -> close acked (ns)"),
            (LATENCY_ADMISSION_WAIT_INTERACTIVE, "histogram", "ckio/shard.rs", "Interactive admission wait, enqueue -> grant (ns)"),
            (LATENCY_ADMISSION_WAIT_BULK, "histogram", "ckio/shard.rs", "Bulk admission wait, enqueue -> grant (ns)"),
            (LATENCY_ADMISSION_WAIT_SCAVENGER, "histogram", "ckio/shard.rs", "Scavenger admission wait, enqueue -> grant (ns)"),
            (LATENCY_PFS_READ, "histogram", "pfs/model.rs", "PFS read RPC service time, issue -> complete (ns)"),
            (LATENCY_ASSEMBLY, "histogram", "ckio/assembler.rs", "client-read assembly latency, request -> last piece (ns)"),
            (LATENCY_PEER_FETCH, "histogram", "ckio/buffer.rs", "peer-fetch round trip, sent -> chunk received (ns)"),
            (FAULT_TRANSIENT, "counter", "pfs/model.rs", "PFS reads completed with a transient error"),
            (FAULT_PERSISTENT, "counter", "pfs/model.rs", "PFS reads completed with a persistent error"),
            (FAULT_SHORT, "counter", "pfs/model.rs", "PFS reads returning fewer valid bytes than asked"),
            (FAULT_STRAGGLER, "counter", "pfs/model.rs", "PFS reads stretched by a straggler OST"),
            (RETRY_ATTEMPTS, "counter", "ckio/buffer.rs", "PFS read re-issues (attempts beyond the first)"),
            (RETRY_TIMEOUTS, "counter", "ckio/buffer.rs", "read deadlines expired at the buffer"),
            (RETRY_HEDGES, "counter", "ckio/buffer.rs", "hedged duplicate reads issued past deadline"),
            (RETRY_LATE, "counter", "ckio/buffer.rs", "completions of already-abandoned attempts, dropped"),
            (RETRY_GAVE_UP, "counter", "ckio/buffer.rs", "extents abandoned after the retry budget"),
            (SESSION_DEGRADED, "counter", "ckio/buffer.rs", "client-read bytes answered from degraded slots"),
            (GOV_RECLAIMED, "counter", "ckio/shard.rs", "tickets and queued demand reclaimed from dead owners"),
            (PLACE_PIECE_SAME_PE, "counter", "ckio/assembler.rs", "piece bytes delivered from a buffer on the assembler's PE"),
            (PLACE_PIECE_CROSS_PE, "counter", "ckio/assembler.rs", "piece bytes delivered from a buffer on another PE"),
            (CONSUMER_FLOW_REPORTS, "counter", "ckio/director.rs", "assembler consumer-flow deltas received (FlowAware)"),
            (CONSUMER_MIGRATIONS_ADVISED, "counter", "ckio/director.rs", "consumer migrations advised by the flow matrix"),
            (CONSUMER_ADVICE_SUPPRESSED, "counter", "ckio/director.rs", "advice withheld by hysteresis or the migration budget"),
            (OVERLAP_WINDOWS, "counter", "amt/engine.rs", "admission-wait overlap windows closed"),
            (OVERLAP_BG_ITERS, "counter", "amt/engine.rs", "background-chare tasks run inside overlap windows"),
            (OVERLAP_BG_TIME, "duration", "amt/engine.rs", "background-chare execution time inside overlap windows"),
            (OVERLAP_WINDOW_TIME, "duration", "amt/engine.rs", "total wall span of closed overlap windows"),
            (PFS_WRITE_RPCS, "counter", "pfs/model.rs", "PFS write RPCs issued"),
            (PFS_BYTES_WRITTEN, "counter", "pfs/model.rs", "bytes written to the PFS"),
            (LATENCY_PFS_WRITE, "histogram", "pfs/model.rs", "PFS write RPC service time, issue -> commit (ns)"),
            (WRITE_PUTS, "counter", "ckio/write.rs", "producer put calls completed"),
            (WRITE_BYTES, "counter", "ckio/write.rs", "bytes accepted from producers into write buffers"),
            (WRITE_SESSIONS, "counter", "ckio/director.rs", "write sessions started"),
            (WRITE_FLUSHES, "counter", "ckio/director.rs", "flush barriers completed"),
            (WRITE_EXTENTS, "counter", "ckio/write.rs", "stripe-aligned extents flushed to the PFS"),
            (WRITE_DEGRADED, "counter", "ckio/write.rs", "dirty bytes abandoned after the write retry budget"),
            (STORE_DIRTY, "gauge", "ckio/shard.rs", "dirty bytes held under store claims (summed over shards)"),
            (STORE_DIRTY_WRITEBACKS, "counter", "ckio/shard.rs", "dirty-span evictions that forced a writeback"),
            (STORE_DIRTY_WRITEBACK_BYTES, "counter", "ckio/shard.rs", "bytes flushed by eviction-forced writebacks"),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_charge_value() {
        let mut m = Metrics::new();
        m.count("a", 2);
        m.count("a", 3);
        m.charge("t", 500);
        m.set("v", 1.5);
        m.add("v", 0.5);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.duration("t"), 500);
        assert!((m.value("v") - 2.0).abs() < 1e-12);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn merge_sums() {
        let mut a = Metrics::new();
        a.count("x", 1);
        a.charge("t", 10);
        let mut b = Metrics::new();
        b.count("x", 2);
        b.charge("t", 5);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        assert_eq!(a.duration("t"), 15);
    }

    #[test]
    fn histograms_record_merge_and_report() {
        let mut a = Metrics::new();
        for v in [10u64, 20, 30] {
            a.record(keys::LATENCY_PFS_READ, v);
        }
        let mut b = Metrics::new();
        b.record(keys::LATENCY_PFS_READ, 40);
        a.merge(&b);
        let h = a.histogram(keys::LATENCY_PFS_READ).unwrap();
        assert_eq!(h.count(), 4);
        assert_eq!(h.max(), 40);
        assert_eq!(a.quantile(keys::LATENCY_PFS_READ, 0.5), 20);
        assert_eq!(a.quantile("missing", 0.5), 0);
        assert!(a.histogram("missing").is_none());
        let r = a.report();
        assert!(r.contains("latency histograms:"));
        assert!(r.contains(keys::LATENCY_PFS_READ));
        a.clear();
        assert!(a.histogram(keys::LATENCY_PFS_READ).is_none());
    }

    #[test]
    fn report_contains_entries() {
        let mut m = Metrics::new();
        m.count(keys::TASKS, 7);
        m.charge(keys::NET_BUSY, 1_500_000);
        let r = m.report();
        assert!(r.contains("amt.tasks"));
        assert!(r.contains("7"));
        assert!(r.contains("1.50 ms"));
    }

    /// Every `pub const` key declared in `keys` has exactly one catalog
    /// row with a kind from the fixed vocabulary — so `--dump-metrics`
    /// (and `docs/OBSERVABILITY.md`) can never silently lag the keys.
    #[test]
    fn catalog_covers_every_key() {
        let src = include_str!("mod.rs");
        let keys_mod = src.split("pub mod keys {").nth(1).expect("keys module present");
        let keys_mod = &keys_mod[..keys_mod.find("\n}").expect("keys module closes")];
        let declared: Vec<&str> = keys_mod
            .lines()
            .filter(|l| l.trim().starts_with("pub const "))
            .filter_map(|l| l.split('"').nth(1))
            .collect();
        assert!(declared.len() > 40, "key extraction broke: found {}", declared.len());
        let cat = keys::catalog();
        assert_eq!(cat.len(), declared.len(), "catalog rows != declared keys");
        for d in &declared {
            assert_eq!(
                cat.iter().filter(|(k, ..)| k == d).count(),
                1,
                "key {d} must have exactly one catalog row"
            );
        }
        for (k, kind, emitter, desc) in &cat {
            assert!(
                matches!(*kind, "counter" | "duration" | "gauge" | "histogram"),
                "{k}: unknown kind {kind}"
            );
            assert!(!emitter.is_empty() && !desc.is_empty());
        }
    }
}
