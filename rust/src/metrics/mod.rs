//! Instrumentation: counters, accumulated durations, and report tables.
//!
//! Every subsystem (engine, PFS, network, CkIO, apps) charges into one
//! [`Metrics`] sink; experiment drivers read it back to produce the
//! paper's breakdowns (e.g. §V: I/O vs. data-permutation vs.
//! over-decomposition overhead, and the background-work fractions of
//! Figs. 8–9).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::amt::time::{self, Time};

/// A metrics sink: named counters and named duration accumulators.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    durations: BTreeMap<&'static str, Time>,
    values: BTreeMap<&'static str, f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn count(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    /// Accumulate a duration.
    pub fn charge(&mut self, name: &'static str, d: Time) {
        *self.durations.entry(name).or_insert(0) += d;
    }

    /// Record/overwrite a raw value (gauges, final ratios).
    pub fn set(&mut self, name: &'static str, v: f64) {
        self.values.insert(name, v);
    }

    /// Add to a raw value.
    pub fn add(&mut self, name: &'static str, v: f64) {
        *self.values.entry(name).or_insert(0.0) += v;
    }

    /// Keep the maximum of a raw value (e.g. "last I/O completion time").
    pub fn set_max(&mut self, name: &'static str, v: f64) {
        let e = self.values.entry(name).or_insert(f64::MIN);
        if v > *e {
            *e = v;
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn duration(&self, name: &str) -> Time {
        self.durations.get(name).copied().unwrap_or(0)
    }

    pub fn duration_secs(&self, name: &str) -> f64 {
        time::to_secs(self.duration(name))
    }

    pub fn value(&self, name: &str) -> f64 {
        self.values.get(name).copied().unwrap_or(0.0)
    }

    /// Merge another sink into this one (e.g. per-run → aggregate).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.durations {
            *self.durations.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.values {
            *self.values.entry(k).or_insert(0.0) += v;
        }
    }

    /// Reset everything.
    pub fn clear(&mut self) {
        self.counters.clear();
        self.durations.clear();
        self.values.clear();
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            let _ = writeln!(out, "counters:");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "  {k:40} {v}");
            }
        }
        if !self.durations.is_empty() {
            let _ = writeln!(out, "durations:");
            for (k, v) in &self.durations {
                let _ = writeln!(out, "  {k:40} {}", time::human(*v));
            }
        }
        if !self.values.is_empty() {
            let _ = writeln!(out, "values:");
            for (k, v) in &self.values {
                let _ = writeln!(out, "  {k:40} {v:.6}");
            }
        }
        out
    }
}

/// Well-known metric names, so subsystems and reports agree.
pub mod keys {
    /// Tasks executed by all PE schedulers.
    pub const TASKS: &str = "amt.tasks";
    /// Messages sent (all kinds).
    pub const MSGS: &str = "amt.msgs_sent";
    /// Location-manager forwarding hops (stale caches / in-flight chares).
    pub const FWD_HOPS: &str = "amt.forward_hops";
    /// Chare migrations completed.
    pub const MIGRATIONS: &str = "amt.migrations";
    /// Bytes moved over the interconnect (modeled).
    pub const NET_BYTES: &str = "net.bytes";
    /// Time the interconnect spent serializing data (modeled, summed).
    pub const NET_BUSY: &str = "net.busy";
    /// PFS read RPCs issued.
    pub const PFS_RPCS: &str = "pfs.rpcs";
    /// Bytes read from the PFS.
    pub const PFS_BYTES: &str = "pfs.bytes_read";
    /// Aggregate time OSTs spent servicing requests.
    pub const OST_BUSY: &str = "pfs.ost_busy";
    /// High-water mark of reads simultaneously in flight at the PFS
    /// (gauge; the admission governor's cap is asserted against this).
    pub const PFS_MAX_CONCURRENT: &str = "pfs.max_concurrent_reads";
    /// CkIO: read requests served to clients.
    pub const CKIO_READS: &str = "ckio.reads_served";
    /// CkIO: bytes delivered to clients.
    pub const CKIO_BYTES: &str = "ckio.bytes_delivered";
    /// CkIO: read sessions started.
    pub const SESSIONS: &str = "ckio.sessions";
    /// CkIO: session starts rejected with a structured error.
    pub const SESSIONS_REJECTED: &str = "ckio.sessions_rejected";
    /// CkIO: opens rejected with a structured error (invalid or
    /// conflicting options).
    pub const OPENS_REJECTED: &str = "ckio.opens_rejected";
    /// CkIO: re-opens of an already-open file (refcount sharing).
    pub const REOPENS: &str = "ckio.reopens";
    /// CkIO: duplicate session/file closes observed (idempotent).
    pub const DOUBLE_CLOSE: &str = "ckio.double_close";
    /// Manager: reads answered with a modeled NACK because their session
    /// was already torn down.
    pub const READS_AFTER_CLOSE: &str = "ckio.reads_after_close";
    /// Assembler: accumulated client-read assembly latency.
    pub const ASSEMBLY_LATENCY: &str = "ckio.assembly_latency";
    /// Assembler: late pieces of a closed session, tolerated and dropped.
    pub const PIECES_AFTER_CLOSE: &str = "ckio.pieces_after_close";
    /// Buffer chares: pieces served to assemblers from resident data.
    pub const PIECES_SERVED: &str = "ckio.pieces_served";
    /// Buffer chares: fetches answered with a modeled NACK chunk
    /// (teardown drain).
    pub const PIECES_NACKED: &str = "ckio.pieces_nacked";
    /// Buffer chares: fetch requests received.
    pub const FETCHES: &str = "ckio.fetches";
    /// Buffer chares: fetches arriving after the buffer dropped.
    pub const FETCH_AFTER_DROP: &str = "ckio.fetch_after_drop";
    /// Completion time of the last prefetch I/O (gauge, ns; §V's io_s).
    pub const LAST_IO_NS: &str = "ckio.last_io_ns";
    /// Buffer chares rebound to a parked array instead of reading.
    pub const BUFFERS_REBOUND: &str = "ckio.buffers_rebound";
    /// Sessions that reused a parked buffer array wholesale.
    pub const BUFFER_REUSE: &str = "ckio.buffer_reuse";
    /// Parked arrays evicted to stay under the store budget.
    pub const BUFFER_CACHE_EVICTIONS: &str = "ckio.buffer_cache_evictions";
    /// Span store: peer-fetch bytes served from a resident slot.
    pub const STORE_PEER_SERVED: &str = "ckio.store.peer_served";
    /// Span store: peer fetches that missed (source gone meanwhile).
    pub const STORE_PEER_MISS: &str = "ckio.store.peer_miss";
    /// Span store: bytes served from resident data (peer-fetched slots
    /// and exact-match rebinds) instead of new PFS reads.
    pub const STORE_HIT: &str = "ckio.store.hit_bytes";
    /// Span store: bytes for which a PFS read was actually issued.
    pub const STORE_MISS: &str = "ckio.store.miss_bytes";
    /// Span store: resident bytes released by budget eviction or
    /// file-close purge.
    pub const STORE_EVICTED: &str = "ckio.store.evicted_bytes";
    /// Span store: bytes currently resident in parked arrays (gauge;
    /// maintained as add-deltas by each data-plane shard so the value is
    /// the *sum* across shards, never a single shard's view).
    pub const STORE_RESIDENT: &str = "ckio.store.resident_bytes";
    /// Admission governor: PFS reads deferred because the per-shard
    /// in-flight cap was reached.
    pub const GOV_THROTTLED: &str = "ckio.governor.throttled";
    /// Admission governor: the in-flight cap (gauge; the *sum* of
    /// per-shard caps over the active shards — the service-wide
    /// admission ceiling, and exactly the cap itself when one shard is
    /// active. Since PR 5 configuration happens once at boot
    /// (`ServiceConfig`), which publishes the initial sum; after that
    /// only the AIMD feedback loop can move a shard's cap, as
    /// add-deltas).
    pub const GOV_CAP: &str = "ckio.governor.cap";
    /// Admission governor: cap changes made by the adaptive (AIMD)
    /// feedback loop.
    pub const GOV_ADAPTATIONS: &str = "ckio.governor.adaptations";
    /// Admission governor (PR 5): tickets admitted under the
    /// Interactive QoS class (immediate grants and weighted dequeues
    /// alike; with `GOV_GRANTED_BULK`/`GOV_GRANTED_SCAVENGER` this is
    /// the observable the weighted-fair dequeue ratios show up on).
    pub const GOV_GRANTED_INTERACTIVE: &str = "ckio.governor.class_granted.interactive";
    /// Admission governor (PR 5): tickets admitted under the Bulk class.
    pub const GOV_GRANTED_BULK: &str = "ckio.governor.class_granted.bulk";
    /// Admission governor (PR 5): tickets admitted under the Scavenger
    /// class.
    pub const GOV_GRANTED_SCAVENGER: &str = "ckio.governor.class_granted.scavenger";
    /// Store-aware placement (PR 4): buffer chares whose PE was chosen
    /// by a shard's `PlacementPlan` (dominant peer source) rather than
    /// the fallback policy.
    pub const PLACE_PLANNED: &str = "ckio.place.planned";
    /// Peer-fetched bytes that stayed on one PE (requester and source
    /// colocated — what store-aware placement maximizes).
    pub const PLACE_SAME_PE: &str = "ckio.place.same_pe_fetch";
    /// Peer-fetched bytes that crossed PEs (the Fig. 12 cost store-aware
    /// placement collapses toward zero).
    pub const PLACE_CROSS_PE: &str = "ckio.place.cross_pe_fetch";
    /// Store-aware placement: buffers whose registration found less
    /// peer coverage than their plan promised (a claim owner unclaimed
    /// between `EP_SHARD_PLAN` and `EP_SHARD_REGISTER`; the shortfall
    /// degrades gracefully to PFS reads).
    pub const PLACE_DEGRADED: &str = "ckio.place.degraded";
    /// Data-plane shards: most messages processed by any one shard
    /// (gauge, set by the harness post-run; with `msgs_mean` this is the
    /// shard-imbalance pair).
    pub const SHARD_MSGS_MAX: &str = "ckio.shard.msgs_max";
    /// Data-plane shards: mean messages processed per *active* shard
    /// (gauge, set by the harness post-run).
    pub const SHARD_MSGS_MEAN: &str = "ckio.shard.msgs_mean";
    /// Background-work time accumulated by compute chares (Figs. 8–9).
    pub const BG_WORK: &str = "app.bg_work";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_charge_value() {
        let mut m = Metrics::new();
        m.count("a", 2);
        m.count("a", 3);
        m.charge("t", 500);
        m.set("v", 1.5);
        m.add("v", 0.5);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.duration("t"), 500);
        assert!((m.value("v") - 2.0).abs() < 1e-12);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn merge_sums() {
        let mut a = Metrics::new();
        a.count("x", 1);
        a.charge("t", 10);
        let mut b = Metrics::new();
        b.count("x", 2);
        b.charge("t", 5);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        assert_eq!(a.duration("t"), 15);
    }

    #[test]
    fn report_contains_entries() {
        let mut m = Metrics::new();
        m.count(keys::TASKS, 7);
        m.charge(keys::NET_BUSY, 1_500_000);
        let r = m.report();
        assert!(r.contains("amt.tasks"));
        assert!(r.contains("7"));
        assert!(r.contains("1.50 ms"));
    }
}
