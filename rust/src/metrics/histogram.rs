//! Deterministic log-spaced latency histogram.
//!
//! Fixed bucket layout, no dependencies, mergeable like
//! [`Metrics::merge`](super::Metrics::merge): values below 64 land in
//! exact unit buckets; above that each power-of-two octave is split
//! into 64 sub-buckets (`SUB_BITS = 6`), bounding the relative
//! quantile error at `1/64 ≈ 1.6%`. Bucketing is pure integer math on
//! the value's bit pattern, so the same recordings produce the same
//! quantiles on every platform and in every merge order (bucket counts
//! add element-wise, which is commutative and associative).
//!
//! The harness records latencies in **nanoseconds** (the engine's
//! virtual-clock unit); `quantile` returns the lower bound of the
//! bucket containing the requested rank, clamped into the observed
//! `[min, max]` range.

/// Sub-bucket resolution: each octave above the linear range is split
/// into `2^SUB_BITS` buckets.
pub const SUB_BITS: u32 = 6;
const SUB: u64 = 1 << SUB_BITS; // 64 sub-buckets per octave
/// Total buckets: 64 exact unit buckets + 58 octaves (2^6 ..= 2^63)
/// of 64 sub-buckets each.
pub const BUCKETS: usize = (SUB + (64 - SUB_BITS as u64) * SUB) as usize;

/// A fixed-layout log-spaced histogram of `u64` samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn bucket_of(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let e = 63 - u64::from(v.leading_zeros()); // floor(log2 v), 6..=63
        let frac = (v >> (e - u64::from(SUB_BITS))) & (SUB - 1);
        ((e - u64::from(SUB_BITS)) * SUB + SUB + frac) as usize
    }

    /// Lower bound of bucket `i` (the deterministic quantile
    /// representative).
    fn bucket_floor(i: usize) -> u64 {
        let i = i as u64;
        if i < SUB {
            return i;
        }
        let o = i - SUB;
        let e = o / SUB + u64::from(SUB_BITS);
        let frac = o % SUB;
        (1u64 << e) + (frac << (e - u64::from(SUB_BITS)))
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += u128::from(v);
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// The value at quantile `q` in `[0, 1]`: the lower bound of the
    /// bucket holding the `ceil(q · n)`-th smallest sample, clamped
    /// into the observed range. Within `1/64` of the exact order
    /// statistic; `0` for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        // The extreme order statistics are tracked exactly.
        if rank == 1 {
            return self.min;
        }
        if rank == self.total {
            return self.max;
        }
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_floor(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one. Element-wise bucket
    /// addition: commutative and associative, so any merge order over
    /// the same recordings yields identical quantiles.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        if other.total > 0 {
            if other.min < self.min {
                self.min = other.min;
            }
            if other.max > self.max {
                self.max = other.max;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_total_and_ordered() {
        // Every bucket's floor maps back to that bucket, and floors are
        // strictly increasing — the layout is a partition.
        let mut prev = None;
        for i in 0..BUCKETS {
            let f = Histogram::bucket_floor(i);
            assert_eq!(Histogram::bucket_of(f), i, "floor of bucket {i}");
            if let Some(p) = prev {
                assert!(f > p, "floors must increase at {i}");
            }
            prev = Some(f);
        }
        assert_eq!(Histogram::bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        for q in [0.01, 0.25, 0.5, 0.99] {
            let exact = ((q * 64.0).ceil() as u64).clamp(1, 64) - 1;
            assert_eq!(h.quantile(q), exact, "q={q}");
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 63);
    }

    #[test]
    fn quantile_error_is_bounded() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v * 17); // spread across several octaves
        }
        for q in [0.5, 0.9, 0.99, 0.999] {
            let exact = ((q * 100_000.0).ceil() as u64) * 17;
            let got = h.quantile(q);
            let err = (got as f64 - exact as f64).abs() / exact as f64;
            assert!(err <= 1.0 / 64.0 + 1e-9, "q={q}: got {got}, exact {exact}, err {err}");
        }
        assert_eq!(h.count(), 100_000);
        assert_eq!(h.quantile(1.0), h.max());
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_order_is_invariant() {
        let sets: [&[u64]; 3] = [
            &[1, 5, 900, 70_000, 3_000_000],
            &[2, 2, 2, 1_000_000_000],
            &[64, 65, 127, 128, 40_000_000_000],
        ];
        let hist_of = |idxs: &[usize]| {
            let mut acc = Histogram::new();
            for &i in idxs {
                let mut h = Histogram::new();
                for &v in sets[i] {
                    h.record(v);
                }
                acc.merge(&h);
            }
            acc
        };
        let a = hist_of(&[0, 1, 2]);
        let b = hist_of(&[2, 0, 1]);
        let mut direct = Histogram::new();
        for s in sets {
            for &v in s {
                direct.record(v);
            }
        }
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(a.quantile(q), b.quantile(q), "q={q}");
            assert_eq!(a.quantile(q), direct.quantile(q), "q={q} vs direct");
        }
        assert_eq!(a.count(), direct.count());
        assert_eq!(a.min(), direct.min());
        assert_eq!(a.max(), direct.max());
        assert_eq!(a.mean(), direct.mean());
    }

    #[test]
    fn recording_is_deterministic() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for h in [&mut a, &mut b] {
            let mut x = 0x2545_F491_4F6C_DD1Du64;
            for _ in 0..10_000 {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                h.record(x % 50_000_000);
            }
        }
        for q in [0.5, 0.99, 0.999] {
            assert_eq!(a.quantile(q), b.quantile(q));
        }
    }
}
