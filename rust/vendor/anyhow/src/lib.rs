//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so the small subset of
//! `anyhow` this repository uses — `Error`, `Result`, `anyhow!`,
//! `ensure!`, and the `Context` extension trait — is implemented here as
//! a vendored path dependency. Errors carry a flattened message string;
//! `context` prepends to it, matching `anyhow`'s Display output closely
//! enough for diagnostics and tests.

use std::fmt;

/// A flattened dynamic error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, `anyhow`-style (`"{context}: {cause}"`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`
// (neither does anyhow's), which is what makes this blanket conversion
// coherent alongside the core `impl<T> From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-compatible result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($msg $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($rest:tt)+) => {
        return Err($crate::anyhow!($($rest)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let b = anyhow!("x={} y={:?}", 1, "s");
        assert_eq!(b.to_string(), "x=1 y=\"s\"");
        let msg = String::from("owned");
        let c = anyhow!(msg);
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening foo").unwrap_err();
        assert_eq!(e.to_string(), "opening foo: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn ensure_returns_error() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(inner(5).unwrap(), 5);
        assert!(inner(11).unwrap_err().to_string().contains("11"));
    }
}
