//! Store-aware placement integration tests (PR 4): the plan-then-create
//! session start, its revalidation under races, and the structured
//! open-time rejection of impossible placements.
//!
//! * **Plan placement** — a `StoreAware` session started over live
//!   claims creates each buffer chare on the PE of its dominant peer
//!   source, so every peer fetch is a same-PE copy.
//! * **Plan vs unclaim race** — a session close landing between the
//!   director's `EP_SHARD_PLAN` probe and the new buffers' registration
//!   retracts the claims the plan saw; the start must degrade to the
//!   fallback behavior (PFS reads, `ckio.place.degraded`) without
//!   asserting, and every read still verifies.
//! * **No stale plans** — plans are per-start snapshots, never cached:
//!   after a full close + purge + re-open, a new `StoreAware` start
//!   finds an empty store and lands exactly on the fallback placement.
//! * **Open-time validation** — a placement that can never cover the
//!   resolvable reader count fails `open` with a structured
//!   [`OpenError`] instead of panicking at session start.

use ckio::amt::callback::Callback;
use ckio::amt::chare::ChareRef;
use ckio::amt::engine::{Engine, EngineConfig};
use ckio::amt::topology::Placement;
use ckio::ckio::director::Director;
use ckio::ckio::{
    CkIo, FileOptions, OpenError, ReadResult, ReaderPlacement, Session, SessionId, SessionOptions,
};
use ckio::harness::experiments::assert_service_clean;
use ckio::metrics::keys;
use ckio::pfs::{pattern, FileId, PfsConfig};

const KIB: u64 = 1 << 10;
const MIB: u64 = 1 << 20;

fn verified_engine(file_size: u64) -> (Engine, FileId, CkIo) {
    let mut eng = Engine::new(EngineConfig::sim(2, 4)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot(&mut eng);
    (eng, file, io)
}

fn store_aware_fopts() -> FileOptions {
    FileOptions {
        num_readers: Some(8),
        placement: ReaderPlacement::StoreAware {
            fallback: Box::new(ReaderPlacement::SpreadNodes),
        },
    }
}

fn splintered_sopts() -> SessionOptions {
    SessionOptions { splinter_bytes: Some(16 * KIB), ..Default::default() }
}

fn open_file(eng: &mut Engine, io: &CkIo, file: FileId, size: u64, opts: FileOptions) {
    let fut = eng.future(1);
    io.open_driver(eng, file, size, opts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "open never completed");
}

fn start_session_with(
    eng: &mut Engine,
    io: &CkIo,
    file: FileId,
    offset: u64,
    bytes: u64,
    sopts: SessionOptions,
) -> Session {
    let fut = eng.future(1);
    io.start_session_driver(eng, file, offset, bytes, sopts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session never became ready");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    p.take::<Session>()
}

fn start_session(eng: &mut Engine, io: &CkIo, file: FileId, offset: u64, bytes: u64) -> Session {
    start_session_with(eng, io, file, offset, bytes, splintered_sopts())
}

fn close_session(eng: &mut Engine, io: &CkIo, sid: SessionId) {
    let fut = eng.future(1);
    io.close_session_driver(eng, sid, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session close never completed");
}

fn close_file(eng: &mut Engine, io: &CkIo, file: FileId) {
    let fut = eng.future(1);
    io.close_file_driver(eng, file, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "file close never completed");
}

fn read_verified(eng: &mut Engine, io: &CkIo, s: &Session, file: FileId, offset: u64, len: u64) {
    let fut = eng.future(1);
    io.read_driver(eng, 0, s, offset, len, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "read callback never fired");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    let r = p.take::<ReadResult>();
    assert_eq!(r.len, len);
    let bytes = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
    assert_eq!(pattern::verify(file, offset, bytes), None, "corrupt read");
}

// ---------------------------------------------------------------------
// 1. Plan placement colocates buffers with their peer sources
// ---------------------------------------------------------------------

/// Session B's window is shifted by one B-sized span against session
/// A's partition, so B's buffer j is fully contained in A's buffer
/// `(1 + j) / 2` — at a *different* index. The plan must place each B
/// buffer on its source's PE (not at index-based fallback position),
/// and every peer fetch must then stay on-PE.
#[test]
fn store_aware_places_buffers_on_peer_source_pes() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    open_file(&mut eng, &io, file, size, store_aware_fopts());

    // Session A: the whole file, 8 buffers of 128 KiB.
    let sa = start_session(&mut eng, &io, file, 0, size);
    assert_eq!(eng.core.metrics.counter(keys::PLACE_PLANNED), 0, "nothing resident yet");

    // Session B: [64 KiB, 576 KiB), 8 buffers of 64 KiB.
    let span = size / 16;
    let sb = start_session(&mut eng, &io, file, span, size / 2);
    assert_eq!(
        eng.core.metrics.counter(keys::PLACE_PLANNED),
        8,
        "every B buffer has a resident source and must be plan-placed"
    );
    for j in 0..8u32 {
        let source = (1 + j) / 2;
        assert_eq!(
            eng.pe_of(ChareRef::new(sb.buffers, j)),
            eng.pe_of(ChareRef::new(sa.buffers, source)),
            "B buffer {j} must sit on the PE of its dominant source (A buffer {source})"
        );
    }
    // All of B's bytes came off A's resident data without crossing PEs.
    assert_eq!(eng.core.metrics.counter(keys::PLACE_CROSS_PE), 0);
    assert_eq!(eng.core.metrics.counter(keys::PLACE_SAME_PE), size / 2);
    assert_eq!(eng.core.metrics.counter(keys::PLACE_DEGRADED), 0);
    read_verified(&mut eng, &io, &sb, file, span, size / 2);

    close_session(&mut eng, &io, sb.id);
    close_session(&mut eng, &io, sa.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

// ---------------------------------------------------------------------
// 2. A plan racing a concurrent unclaim degrades to the fallback
// ---------------------------------------------------------------------

/// Session A closes in the same scheduling window as session B starts:
/// the director's plan probe races A's buffers' `EP_SHARD_UNCLAIM`s. If
/// the plan snapshot still saw A's claims, B's registration (which runs
/// strictly later) finds them gone and must degrade — fallback PFS
/// reads, `ckio.place.degraded` counted, no assert anywhere — and B's
/// data must still verify byte-for-byte.
#[test]
fn plan_racing_a_session_close_degrades_to_fallback() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    open_file(&mut eng, &io, file, size, store_aware_fopts());

    let sa = start_session(&mut eng, &io, file, 0, size);

    // Close A and start B back-to-back, no quiescence in between.
    let close_fut = eng.future(1);
    io.close_session_driver(&mut eng, sa.id, Callback::Future(close_fut));
    let ready_fut = eng.future(1);
    io.start_session_driver(
        &mut eng,
        file,
        0,
        size,
        splintered_sopts(),
        Callback::Future(ready_fut),
    );
    eng.run();
    assert!(eng.future_done(close_fut), "A's close must complete");
    assert!(eng.future_done(ready_fut), "B must become ready despite the race");
    let sb = {
        let (_, mut p) = eng.take_future(ready_fut).pop().unwrap();
        p.take::<Session>()
    };

    // Whichever side the snapshot caught: a plan that promised coverage
    // which registration could not confirm must be counted as degraded
    // (and one that already saw the unclaim promises nothing). Either
    // way B serves its whole range, verified, with no stranded state.
    let planned = eng.core.metrics.counter(keys::PLACE_PLANNED);
    let degraded = eng.core.metrics.counter(keys::PLACE_DEGRADED);
    if planned > 0 {
        assert!(
            degraded > 0,
            "a plan over claims that vanished must revalidate as degraded (planned {planned})"
        );
    }
    read_verified(&mut eng, &io, &sb, file, 0, size);
    // B re-read everything it could not peer-fetch: total delivery is
    // still exact (the PFS saw the file once for A plus B's fallback).
    close_session(&mut eng, &io, sb.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

// ---------------------------------------------------------------------
// 3. Re-open never sees a stale plan
// ---------------------------------------------------------------------

/// Plans are snapshots correlated by token, never cached by file: after
/// a full close (purging the shard) and a re-open, a `StoreAware` start
/// must get an *empty* plan — no buffer plan-placed, the array exactly
/// at the fallback placement — rather than resurrecting the previous
/// generation's layout.
#[test]
fn reopen_does_not_reuse_a_stale_plan() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    open_file(&mut eng, &io, file, size, store_aware_fopts());

    // First generation: warm the store, then tear everything down.
    let sa = start_session(&mut eng, &io, file, 0, size);
    let sb = start_session(&mut eng, &io, file, size / 16, size / 2);
    let planned_gen1 = eng.core.metrics.counter(keys::PLACE_PLANNED);
    assert_eq!(planned_gen1, 8, "generation 1 must be plan-placed");
    close_session(&mut eng, &io, sb.id);
    close_session(&mut eng, &io, sa.id);
    close_file(&mut eng, &io, file);

    // Second generation: same file id, same shapes, empty store.
    open_file(&mut eng, &io, file, size, store_aware_fopts());
    let sc = start_session(&mut eng, &io, file, size / 16, size / 2);
    assert_eq!(
        eng.core.metrics.counter(keys::PLACE_PLANNED),
        planned_gen1,
        "a start over a purged store must not be plan-placed"
    );
    // The array sits exactly where the fallback (SpreadNodes) puts it.
    let expected = Placement::RoundRobinNodes.place(&eng.core.topo, 8);
    for j in 0..8u32 {
        assert_eq!(
            eng.pe_of(ChareRef::new(sc.buffers, j)),
            expected[j as usize],
            "buffer {j} must sit at its fallback position"
        );
    }
    read_verified(&mut eng, &io, &sc, file, size / 16, size / 2);
    close_session(&mut eng, &io, sc.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

// ---------------------------------------------------------------------
// 4. Impossible placements fail open with a structured error
// ---------------------------------------------------------------------

/// Regression (PR 4 satellite): `ReaderPlacement::Explicit` with fewer
/// PEs than the resolvable reader count used to panic inside
/// `to_placement` at session start. It now fails the `open` itself with
/// a structured [`OpenError`] on the callback, creates no file state
/// anywhere, and leaves the service fully usable.
#[test]
fn short_explicit_placement_fails_open_with_structured_error() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    let bad = FileOptions {
        num_readers: Some(4),
        placement: ReaderPlacement::Explicit(vec![0, 1]),
    };
    let fut = eng.future(1);
    io.open_driver(&mut eng, file, size, bad, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "rejected open must still fire its callback");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    assert_eq!(
        p.take::<OpenError>(),
        OpenError::PlacementTooShort { need: 4, got: 2 },
        "the callback must carry the structured error"
    );
    assert_eq!(eng.core.metrics.counter("ckio.opens_rejected"), 1);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0, "no file state created");

    // A StoreAware fallback nested inside StoreAware is rejected too.
    let nested = FileOptions {
        num_readers: Some(2),
        placement: ReaderPlacement::StoreAware {
            fallback: Box::new(ReaderPlacement::StoreAware {
                fallback: Box::new(ReaderPlacement::SpreadNodes),
            }),
        },
    };
    let fut = eng.future(1);
    io.open_driver(&mut eng, file, size, nested, Callback::Future(fut));
    eng.run();
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    assert_eq!(p.take::<OpenError>(), OpenError::RecursiveFallback);

    // The service is intact: a valid open + session works afterwards.
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));
    let s = start_session(&mut eng, &io, file, 0, size);
    read_verified(&mut eng, &io, &s, file, 0, size);
    close_session(&mut eng, &io, s.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

/// The split-phase pattern of sending `open` and `startReadSession`
/// back-to-back (without waiting for the open callback) must stay safe
/// when the open is *rejected*: the pipelined start gets the same
/// structured error on its own callback — never a director panic — and
/// the file is fully usable after a subsequent valid open.
#[test]
fn session_start_pipelined_behind_rejected_open_gets_the_error() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    let bad = FileOptions {
        num_readers: Some(4),
        placement: ReaderPlacement::Explicit(vec![0]),
    };
    let opened = eng.future(1);
    let ready = eng.future(1);
    // Injected together: the start is queued behind the rejected open.
    io.open_driver(&mut eng, file, size, bad, Callback::Future(opened));
    io.start_session_driver(
        &mut eng,
        file,
        0,
        size,
        SessionOptions::default(),
        Callback::Future(ready),
    );
    eng.run();
    assert!(eng.future_done(opened) && eng.future_done(ready));
    let (_, mut p) = eng.take_future(ready).pop().unwrap();
    assert_eq!(
        p.take::<OpenError>(),
        OpenError::PlacementTooShort { need: 4, got: 1 },
        "the pipelined start must surface the open's structured error"
    );
    assert_eq!(eng.core.metrics.counter("ckio.sessions_rejected"), 1);

    // A later valid open supersedes the rejection: the same file opens
    // and serves sessions normally.
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));
    let s = start_session(&mut eng, &io, file, 0, size);
    read_verified(&mut eng, &io, &s, file, 0, size);
    close_session(&mut eng, &io, s.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

// ---------------------------------------------------------------------
// 5. Per-session placement overrides (PR 5)
// ---------------------------------------------------------------------

/// A session may override the file's placement for itself only
/// (`SessionOptions::placement_override`): the override is validated at
/// session start against that session's resolved reader count — an
/// impossible one fails the ready callback with the same structured
/// error an impossible open gets — and a valid one places exactly this
/// session's array without touching the file policy.
#[test]
fn session_placement_override_is_validated_and_applied_per_session() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    // File policy: spread. Session override: pack onto explicit PEs.
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));

    // An override that cannot cover the resolved reader count fails the
    // ready callback with a structured error (never a panic).
    let bad = SessionOptions {
        placement_override: Some(ReaderPlacement::Explicit(vec![3])),
        ..Default::default()
    };
    let ready = eng.future(1);
    io.start_session_driver(&mut eng, file, 0, size, bad, Callback::Future(ready));
    eng.run();
    assert!(eng.future_done(ready), "rejected start must still fire its callback");
    let (_, mut p) = eng.take_future(ready).pop().unwrap();
    assert_eq!(p.take::<OpenError>(), OpenError::PlacementTooShort { need: 2, got: 1 });
    assert_eq!(eng.core.metrics.counter("ckio.sessions_rejected"), 1);

    // A valid override places exactly where it says, for this session
    // only: the next default session is back at the file's policy.
    let pinned = SessionOptions {
        placement_override: Some(ReaderPlacement::Explicit(vec![3, 3])),
        ..Default::default()
    };
    let s1 = start_session_with(&mut eng, &io, file, 0, size, pinned);
    for b in 0..2u32 {
        assert_eq!(eng.pe_of(ChareRef::new(s1.buffers, b)).0, 3, "override must pin buffer {b}");
    }
    read_verified(&mut eng, &io, &s1, file, 0, size);
    let s2 = start_session_with(&mut eng, &io, file, 0, size, SessionOptions::default());
    let expected = Placement::RoundRobinNodes.place(&eng.core.topo, 2);
    for b in 0..2u32 {
        assert_eq!(
            eng.pe_of(ChareRef::new(s2.buffers, b)),
            expected[b as usize],
            "a default session must use the file placement, not a leaked override"
        );
    }
    close_session(&mut eng, &io, s1.id);
    close_session(&mut eng, &io, s2.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

/// The effective placement is part of the parked-array rebind key: a
/// session with a `placement_override` must never rebind an array
/// parked at the file-policy PEs, and — the mirror — a session without
/// one must never rebind an array parked at override PEs. Silently
/// inheriting the other layout is exactly the ignore-the-caller footgun
/// PR 5 removes.
#[test]
fn placement_override_never_rebinds_across_placements() {
    let size = MIB;
    let (mut eng, file, io) = verified_engine(size);
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));

    // Session A parks its array at the file's spread placement.
    let reuse = SessionOptions { reuse_buffers: true, ..Default::default() };
    let sa = start_session_with(&mut eng, &io, file, 0, size, reuse.clone());
    close_session(&mut eng, &io, sa.id);

    // Session B: identical shape + reuse, but with an override. It must
    // NOT rebind A's parked array: fresh buffers, on the override PEs.
    let pinned = SessionOptions {
        placement_override: Some(ReaderPlacement::Explicit(vec![5, 5])),
        ..reuse.clone()
    };
    let sb = start_session_with(&mut eng, &io, file, 0, size, pinned);
    assert_eq!(
        eng.core.metrics.counter("ckio.buffer_reuse"),
        0,
        "an override must miss a parked array at the file-policy placement"
    );
    for b in 0..2u32 {
        assert_eq!(eng.pe_of(ChareRef::new(sb.buffers, b)).0, 5, "buffer {b} must obey override");
    }
    // The fresh array still peer-fetches A's resident claims — no
    // second trip to the PFS for the same bytes.
    read_verified(&mut eng, &io, &sb, file, 0, size);
    assert_eq!(eng.core.metrics.counter("pfs.bytes_read"), size, "B must dedup against A");
    close_session(&mut eng, &io, sb.id); // parks B's array under its override key

    // Mirror: session C (no override) must not inherit B's PE-5 array.
    // It may legitimately rebind A's (parked under the same spread
    // placement) — either way its buffers sit off PE 5.
    let sc = start_session_with(&mut eng, &io, file, 0, size, reuse);
    for b in 0..2u32 {
        assert_ne!(
            eng.pe_of(ChareRef::new(sc.buffers, b)).0,
            5,
            "buffer {b} must not inherit the override session's placement"
        );
    }
    close_session(&mut eng, &io, sc.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}
