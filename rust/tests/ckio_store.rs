//! Span-store integration tests (PR 2): partial-overlap serving edge
//! cases, driven from the driver-side API for precise sequencing.
//!
//! * **Split serve** — a parked array that only covers a *prefix* of a
//!   new session: the resident prefix comes from the store (peer
//!   fetches), the remainder from the PFS, and contents stay verified.
//! * **Stripe boundary** — the split point lands exactly on a PFS stripe
//!   boundary (the case where off-by-one extent math would corrupt or
//!   double-read).
//! * **Eviction racing a pending close** — a tight byte budget forces
//!   LRU eviction of a parked array while a new overlapping session is
//!   starting; whichever interleaving the director sees, reads verify
//!   (stale claims degrade to peer misses and PFS fallback, never to
//!   corruption or a stranded callback).

use ckio::amt::callback::Callback;
use ckio::amt::engine::{Engine, EngineConfig};
use ckio::ckio::director::Director;
use ckio::ckio::{CkIo, FileOptions, ReadResult, ServiceConfig, Session, SessionId, SessionOptions};
use ckio::harness::experiments::assert_service_clean;
use ckio::pfs::{pattern, FileId, PfsConfig};

const MIB: u64 = 1 << 20;

fn verified_engine(file_size: u64, cfg: ServiceConfig) -> (Engine, FileId, CkIo) {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot_with(&mut eng, cfg).expect("valid ServiceConfig");
    (eng, file, io)
}

/// Start a session over `[offset, offset+bytes)` and run to quiescence
/// (the greedy prefetch completes), returning the session handle.
fn start_session(
    eng: &mut Engine,
    io: &CkIo,
    file: FileId,
    offset: u64,
    bytes: u64,
    sopts: SessionOptions,
) -> Session {
    let fut = eng.future(1);
    io.start_session_driver(eng, file, offset, bytes, sopts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session never became ready");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    p.take::<Session>()
}

/// Close a session and run to quiescence.
fn close_session(eng: &mut Engine, io: &CkIo, sid: SessionId) {
    let fut = eng.future(1);
    io.close_session_driver(eng, sid, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session close never completed");
}

/// Read `[offset, offset+len)` through PE 0's manager and verify every
/// byte against the deterministic file pattern.
fn read_verified(eng: &mut Engine, io: &CkIo, s: &Session, file: FileId, offset: u64, len: u64) {
    let fut = eng.future(1);
    io.read_driver(eng, 0, s, offset, len, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "read callback never fired");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    let r = p.take::<ReadResult>();
    assert_eq!(r.len, len);
    let bytes = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
    assert_eq!(
        pattern::verify(file, offset, bytes),
        None,
        "corrupt read at offset {offset} (len {len})"
    );
}

/// A parked array covering only the first half of a new session splits
/// the serve: the resident half is peer-fetched from the store (zero new
/// PFS traffic), the other half is read from the PFS — exactly once.
#[test]
fn parked_array_split_serves_partial_overlap() {
    let size = 2 * MIB;
    let (mut eng, file, io) = verified_engine(size, ServiceConfig::default());
    let sopts = SessionOptions {
        splinter_bytes: Some(64 << 10),
        reuse_buffers: true,
        ..Default::default()
    };
    // The driver holds the file open across sessions.
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);

    // Session A prefetches the first half, then parks.
    let sa = start_session(&mut eng, &io, file, 0, size / 2, sopts.clone());
    read_verified(&mut eng, &io, &sa, file, 0, size / 2);
    close_session(&mut eng, &io, sa.id);
    let pfs_after_a = eng.core.metrics.counter("pfs.bytes_read");
    assert_eq!(pfs_after_a, size / 2, "session A reads exactly its half");
    assert_eq!(io.cached_buffer_arrays(&eng), 1);

    // Session B spans the whole file: its first half is served from A's
    // parked array (split serve), only the second half hits the PFS.
    let sb = start_session(&mut eng, &io, file, 0, size, sopts);
    read_verified(&mut eng, &io, &sb, file, 0, size);
    let pfs_after_b = eng.core.metrics.counter("pfs.bytes_read");
    assert_eq!(
        pfs_after_b - pfs_after_a,
        size / 2,
        "session B must only read the non-resident half from the PFS"
    );
    assert_eq!(
        eng.core.metrics.counter("ckio.store.hit_bytes"),
        size / 2,
        "the resident half must be served out of the span store"
    );

    close_session(&mut eng, &io, sb.id);
    assert_service_clean(&eng, &io);
    let cfut = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(cfut));
    eng.run();
    assert!(eng.future_done(cfut));
    assert_eq!(io.cached_buffer_arrays(&eng), 0, "file close purges parked arrays");
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

/// The resident/PFS split lands exactly on a stripe boundary: a parked
/// array over stripe 0 serves the first buffer of a session that crosses
/// into stripe 1, with no double-read and no corruption at the seam.
#[test]
fn split_serve_at_stripe_boundary_is_exact() {
    let size = 8 * MIB; // default stripe size is 4 MiB
    let (mut eng, file, io) = verified_engine(size, ServiceConfig::default());
    let stripe = eng.core.sim_pfs().cfg.stripe_size;
    assert_eq!(stripe, 4 * MIB, "test assumes the default stripe size");
    let sopts = SessionOptions { reuse_buffers: true, ..Default::default() };
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);

    // Session A covers exactly stripe 0 ([0, 4 MiB)), then parks.
    let sa = start_session(&mut eng, &io, file, 0, stripe, sopts.clone());
    close_session(&mut eng, &io, sa.id);
    let pfs_after_a = eng.core.metrics.counter("pfs.bytes_read");
    assert_eq!(pfs_after_a, stripe);

    // Session B straddles the boundary: [2 MiB, 6 MiB). Its first buffer
    // ([2 MiB, 4 MiB)) is fully inside A's claim; its second
    // ([4 MiB, 6 MiB)) starts exactly at the stripe boundary and must be
    // read from the PFS, once.
    let sb = start_session(&mut eng, &io, file, stripe / 2, stripe, sopts);
    // The read crosses the resident/PFS seam at the stripe boundary.
    read_verified(&mut eng, &io, &sb, file, stripe / 2, stripe);
    let pfs_after_b = eng.core.metrics.counter("pfs.bytes_read");
    assert_eq!(
        pfs_after_b - pfs_after_a,
        stripe / 2,
        "only the beyond-boundary half may touch the PFS"
    );
    assert_eq!(eng.core.metrics.counter("ckio.store.hit_bytes"), stripe / 2);

    close_session(&mut eng, &io, sb.id);
    assert_service_clean(&eng, &io);
}

/// A tight byte budget evicts a parked array while a new overlapping
/// session races it through the director. Whichever side wins, every
/// read completes verified (a stale claim degrades to a peer miss and a
/// PFS fallback), eviction is charged, and nothing leaks.
#[test]
fn eviction_racing_a_pending_close_stays_correct() {
    let size = 2 * MIB;
    // Budget and shard pin are service scope (PR 5): one shard so the
    // budget is not split, and exactly one parked half-file array fits.
    let cfg = ServiceConfig {
        store_budget_bytes: Some(MIB),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let (mut eng, file, io) = verified_engine(size, cfg);
    let sopts = SessionOptions {
        splinter_bytes: Some(128 << 10),
        reuse_buffers: true,
        ..Default::default()
    };
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);

    // A parks [0, 1 MiB); it fits the budget.
    let sa = start_session(&mut eng, &io, file, 0, MIB, sopts.clone());
    close_session(&mut eng, &io, sa.id);

    // B covers [1 MiB, 2 MiB). Its close parks a second 1 MiB array,
    // which must evict A. Session C ([512 KiB, 1.5 MiB)) starts in the
    // same scheduling window, overlapping both A (maybe mid-eviction)
    // and B (mid-park) — inject both without quiescing in between.
    let sb = start_session(&mut eng, &io, file, MIB, MIB, sopts.clone());
    let close_fut = eng.future(1);
    io.close_session_driver(&mut eng, sb.id, Callback::Future(close_fut));
    let ready_fut = eng.future(1);
    io.start_session_driver(&mut eng, file, MIB / 2, MIB, sopts, Callback::Future(ready_fut));
    eng.run();
    assert!(eng.future_done(close_fut), "B's close must complete");
    assert!(eng.future_done(ready_fut), "C must become ready");
    let sc = {
        let (_, mut p) = eng.take_future(ready_fut).pop().unwrap();
        p.take::<Session>()
    };

    // C reads across its whole range — through whatever mix of parked
    // arrays, peer misses, and PFS fallbacks the race produced.
    read_verified(&mut eng, &io, &sc, file, MIB / 2, MIB);
    // The budget held: parking B evicted A's resident megabyte.
    assert!(
        eng.core.metrics.counter("ckio.store.evicted_bytes") >= MIB,
        "parking B over a 1 MiB budget must evict A"
    );
    assert!(
        io.store_resident_bytes(&eng) <= MIB,
        "resident bytes exceed the configured budget"
    );

    close_session(&mut eng, &io, sc.id);
    assert_service_clean(&eng, &io);
    let cfut = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(cfut));
    eng.run();
    assert!(eng.future_done(cfut));
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}
