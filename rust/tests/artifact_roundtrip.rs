//! AOT round-trip: load the JAX/Pallas-lowered HLO artifacts and check
//! their numerics against an independent Rust-side reference. This is
//! the full Layer-1/2 ⇄ Layer-3 bridge test; it requires
//! `make artifacts` to have run (skips cleanly otherwise).

use ckio::runtime::{ArtifactRuntime, TensorF32};

const EPS2: f32 = 1e-4;

/// Rust-side all-pairs gravity oracle (mirrors kernels/ref.py).
fn gravity_ref(pos: &[f32], mass: &[f32], n: usize) -> Vec<f32> {
    let mut acc = vec![0f32; n * 3];
    for i in 0..n {
        for j in 0..n {
            let dx = [
                pos[3 * j] - pos[3 * i],
                pos[3 * j + 1] - pos[3 * i + 1],
                pos[3 * j + 2] - pos[3 * i + 2],
            ];
            let r2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2] + EPS2;
            let w = mass[j] / (r2 * r2.sqrt());
            for k in 0..3 {
                acc[3 * i + k] += w * dx[k];
            }
        }
    }
    acc
}

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("gravity_n256.hlo.txt").exists().then_some(dir)
}

fn lcg(state: &mut u64) -> f32 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
}

#[test]
fn gravity_artifact_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = ArtifactRuntime::cpu().unwrap();
    rt.load("gravity_n256", dir.join("gravity_n256.hlo.txt")).unwrap();

    let n = 256usize;
    let mut st = 42u64;
    let pos: Vec<f32> = (0..n * 3).map(|_| lcg(&mut st) * 2.0).collect();
    let vel = vec![0f32; n * 3];
    let mass: Vec<f32> = (0..n).map(|_| lcg(&mut st).abs() + 0.5).collect();
    let dt = 1e-3f32;

    let outs = rt
        .execute(
            "gravity_n256",
            &[
                TensorF32::new(vec![n as i64, 3], pos.clone()),
                TensorF32::new(vec![n as i64, 3], vel.clone()),
                TensorF32::new(vec![n as i64], mass.clone()),
                TensorF32::scalar(dt),
            ],
        )
        .unwrap();
    // (pos', vel', acc, acc_norm)
    assert_eq!(outs.len(), 4);
    let acc = &outs[2];
    assert_eq!(acc.dims, vec![n as i64, 3]);

    let want = gravity_ref(&pos, &mass, n);
    let mut max_abs: f32 = 0.0;
    for (g, w) in acc.data.iter().zip(want.iter()) {
        max_abs = max_abs.max((g - w).abs());
    }
    // f32 all-pairs with different summation orders: small tolerance.
    assert!(max_abs < 2e-2, "max_abs={max_abs}");

    // vel' = vel + dt*acc, pos' = pos + dt*vel'
    for i in 0..n * 3 {
        let v2 = vel[i] + dt * acc.data[i];
        assert!((outs[1].data[i] - v2).abs() < 1e-4);
        let p2 = pos[i] + dt * v2;
        assert!((outs[0].data[i] - p2).abs() < 1e-4);
    }
    // acc_norm positive scalar
    assert_eq!(outs[3].dims, vec![1]);
    assert!(outs[3].data[0] > 0.0);
}

#[test]
fn ingest_artifact_decodes_and_permutes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = ArtifactRuntime::cpu().unwrap();
    rt.load("ingest_n256", dir.join("ingest_n256.hlo.txt")).unwrap();

    let n = 256usize;
    // raw[i][f] = i for all fields; idx reverses; scale=2, offset=f.
    let mut raw = vec![0f32; n * 8];
    for i in 0..n {
        for f in 0..8 {
            raw[i * 8 + f] = i as f32;
        }
    }
    let idx: Vec<f32> = (0..n).rev().map(|i| i as f32).collect();
    let scale = vec![2f32; 8];
    let offset: Vec<f32> = (0..8).map(|f| f as f32).collect();

    let outs = rt
        .execute(
            "ingest_n256",
            &[
                TensorF32::new(vec![n as i64, 8], raw),
                TensorF32::new(vec![n as i64], idx),
                TensorF32::new(vec![8], scale),
                TensorF32::new(vec![8], offset),
            ],
        )
        .unwrap();
    // (fields, total_mass, com)
    assert_eq!(outs.len(), 3);
    let fields = &outs[0];
    assert_eq!(fields.dims, vec![n as i64, 8]);
    // Row i of output = decoded row idx[i] = (n-1-i): value*2 + f.
    for i in 0..n {
        let src = (n - 1 - i) as f32;
        for f in 0..8 {
            let want = src * 2.0 + f as f32;
            let got = fields.data[i * 8 + f];
            assert!((got - want).abs() < 1e-4, "row {i} field {f}: {got} vs {want}");
        }
    }
    // total mass = sum over decoded field 0 = sum(2i) = n(n-1)
    let total = outs[1].data[0];
    let want_total = (n * (n - 1)) as f32;
    assert!((total - want_total).abs() / want_total < 1e-5, "total={total}");
}

#[test]
fn load_dir_finds_all_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = ArtifactRuntime::cpu().unwrap();
    let names = rt.load_dir(&dir).unwrap();
    assert!(names.iter().any(|n| n == "gravity_n256"));
    assert!(names.iter().any(|n| n == "gravity_n4096"));
    assert!(names.iter().any(|n| n == "ingest_n256"));
    assert!(names.iter().any(|n| n == "ingest_n4096"));
}
