//! Collective output plane integration tests (PR 10), driven from the
//! driver-side API for precise sequencing.
//!
//! * **Read-after-write residency** — a closed write session leaves its
//!   bytes parked as store claims; a following read session over the
//!   same range is served 100% from residency (zero PFS read bytes) and
//!   byte-verified against the file pattern.
//! * **Dirty eviction** — a lazily-parked (dirty) array pushed out by
//!   the store budget is written back before it is dropped; nothing is
//!   lost and the bytes reach the PFS exactly once.
//! * **Fault plane** — with transient write faults injected, the flush
//!   barrier still drains every byte durably, the close callback fires
//!   exactly once, and the retry plane (not degradation) absorbs the
//!   faults.
//! * **Mixed QoS** — a writer and a reader contend on one governed
//!   shard: both classes register, the cap throttles, both finish, and
//!   quiescence is clean.

use ckio::amt::callback::Callback;
use ckio::amt::engine::{Engine, EngineConfig};
use ckio::ckio::director::Director;
use ckio::ckio::{
    CkIo, FileOptions, QosClass, ReadResult, RetryPolicy, ServiceConfig, Session, SessionId,
    SessionOptions, SessionOutcome, WriteOptions,
};
use ckio::harness::experiments::assert_service_clean;
use ckio::pfs::{pattern, FaultPlan, FileId, PfsConfig};

const MIB: u64 = 1 << 20;
const PIECE: u64 = 64 << 10;

fn write_engine(file_size: u64, cfg: ServiceConfig, pfs: PfsConfig) -> (Engine, FileId, CkIo) {
    let mut eng = Engine::new(EngineConfig::sim(2, 2).with_seed(42)).with_sim_pfs(pfs);
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot_with(&mut eng, cfg).expect("valid ServiceConfig");
    (eng, file, io)
}

fn clean_pfs() -> PfsConfig {
    PfsConfig { materialize: true, noise_sigma: 0.0, ..PfsConfig::default() }
}

/// Start a write session over `[offset, offset+bytes)` and run to
/// quiescence, returning the session handle.
fn start_write(
    eng: &mut Engine,
    io: &CkIo,
    file: FileId,
    offset: u64,
    bytes: u64,
    sopts: SessionOptions,
    wopts: WriteOptions,
) -> Session {
    let fut = eng.future(1);
    io.start_write_driver(eng, file, offset, bytes, sopts, wopts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "write session never became ready");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    p.take::<Session>()
}

/// Scatter `[offset, offset+len)` as `PIECE`-sized puts round-robined
/// across all PEs, run to quiescence, and assert every put was acked.
fn put_all(eng: &mut Engine, io: &CkIo, s: &Session, offset: u64, len: u64) {
    let npes = eng.core.topo.npes();
    let npieces = len.div_ceil(PIECE) as u32;
    let fut = eng.future(npieces);
    let mut o = offset;
    let mut i = 0u32;
    while o < offset + len {
        let l = PIECE.min(offset + len - o);
        io.write_driver(eng, i % npes, s, o, l, Callback::Future(fut));
        o += l;
        i += 1;
    }
    eng.run();
    assert!(eng.future_done(fut), "not every put was acked");
    let acked: u64 = eng
        .take_future(fut)
        .into_iter()
        .map(|(_, mut p)| p.take::<ckio::ckio::write::WriteResult>().len)
        .sum();
    assert_eq!(acked, len, "acked bytes must cover the scatter");
}

/// Close a write session and return its (exactly-once) outcome.
fn close_write(eng: &mut Engine, io: &CkIo, sid: SessionId) -> SessionOutcome {
    let fut = eng.future(1);
    io.close_write_driver(eng, sid, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "write close never completed");
    let mut fired = eng.take_future(fut);
    assert_eq!(fired.len(), 1, "close callback must fire exactly once");
    let (_, mut p) = fired.pop().unwrap();
    p.take::<SessionOutcome>()
}

/// A write session that flushed and closed leaves every byte resident:
/// the next read session over the range never touches the PFS, and the
/// delivered bytes are identical to what was written.
#[test]
fn read_after_write_is_served_entirely_from_residency() {
    let size = 2 * MIB;
    let (mut eng, file, io) = write_engine(size, ServiceConfig::default(), clean_pfs());
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);

    let ws = start_write(
        &mut eng,
        &io,
        file,
        0,
        size,
        SessionOptions::default(),
        WriteOptions::default(),
    );
    put_all(&mut eng, &io, &ws, 0, size);
    let ffut = eng.future(1);
    io.flush_write_driver(&mut eng, ws.id, Callback::Future(ffut));
    eng.run();
    assert!(eng.future_done(ffut), "flush barrier never completed");
    let o = close_write(&mut eng, &io, ws.id);
    assert_eq!(o.written_bytes, size, "the barrier drains every byte durably");
    assert_eq!(o.dirty_bytes, 0);
    assert_eq!(eng.core.metrics.counter("pfs.bytes_written"), size);
    // Stripe coalescing: 1 MiB extents, not 64 KiB pieces.
    assert_eq!(eng.core.metrics.counter("pfs.write_rpcs"), size / MIB);

    // Read the whole range back: 100% from residency.
    let rfut = eng.future(1);
    io.start_session_driver(
        &mut eng,
        file,
        0,
        size,
        SessionOptions::default(),
        Callback::Future(rfut),
    );
    eng.run();
    assert!(eng.future_done(rfut));
    let rs = {
        let (_, mut p) = eng.take_future(rfut).pop().unwrap();
        p.take::<Session>()
    };
    let dfut = eng.future(1);
    io.read_driver(&mut eng, 0, &rs, 0, size, Callback::Future(dfut));
    eng.run();
    assert!(eng.future_done(dfut), "read callback never fired");
    let (_, mut p) = eng.take_future(dfut).pop().unwrap();
    let r = p.take::<ReadResult>();
    assert_eq!(r.len, size);
    let bytes = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
    assert_eq!(pattern::verify(file, 0, bytes), None, "read-after-write bytes differ");
    assert_eq!(
        eng.core.metrics.counter("pfs.bytes_read"),
        0,
        "read-after-write must be served without a single PFS read"
    );
    assert_eq!(eng.core.metrics.counter("ckio.store.hit_bytes"), size);

    let cfut = eng.future(1);
    io.close_session_driver(&mut eng, rs.id, Callback::Future(cfut));
    eng.run();
    assert!(eng.future_done(cfut));
    assert_service_clean(&eng, &io);
    let ffut = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(ffut));
    eng.run();
    assert!(eng.future_done(ffut));
    // The parked residency was clean (flushed), so the purge drops it
    // without any further writeback.
    assert_eq!(eng.core.metrics.counter("ckio.store.dirty_writebacks"), 0);
    assert_eq!(io.cached_buffer_arrays(&eng), 0, "file close purges parked arrays");
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

/// A lazily-parked dirty array evicted under store pressure is written
/// back before it is dropped — lazy durability loses nothing, it only
/// defers the PFS write to eviction (or purge) time.
#[test]
fn dirty_eviction_forces_writeback_before_drop() {
    let size = 2 * MIB;
    // One shard so the byte budget is not split; exactly one parked
    // 1 MiB array fits.
    let cfg = ServiceConfig {
        store_budget_bytes: Some(MIB),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let (mut eng, file, io) = write_engine(size, cfg, clean_pfs());
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(1), Callback::Ignore);

    // Session A writes [0, 1 MiB) lazily: close parks it dirty — not a
    // byte has reached the PFS.
    let wa = start_write(
        &mut eng,
        &io,
        file,
        0,
        MIB,
        SessionOptions::default(),
        WriteOptions::lazy(),
    );
    put_all(&mut eng, &io, &wa, 0, MIB);
    let oa = close_write(&mut eng, &io, wa.id);
    assert_eq!(oa.dirty_bytes, MIB, "lazy close parks every byte dirty");
    assert_eq!(oa.written_bytes, 0);
    assert_eq!(eng.core.metrics.counter("pfs.bytes_written"), 0);

    // Session B writes [1 MiB, 2 MiB) lazily. Its claims push the store
    // over the 1 MiB budget, evicting A's parked dirty array — which
    // must force a writeback of A's megabyte before the drop.
    let wb = start_write(
        &mut eng,
        &io,
        file,
        MIB,
        MIB,
        SessionOptions::default(),
        WriteOptions::lazy(),
    );
    put_all(&mut eng, &io, &wb, MIB, MIB);
    let ob = close_write(&mut eng, &io, wb.id);
    assert_eq!(ob.dirty_bytes, MIB);
    assert!(
        eng.core.metrics.counter("ckio.store.dirty_writebacks") >= 1,
        "evicting a dirty park must force a writeback"
    );
    assert_eq!(
        eng.core.metrics.counter("ckio.store.dirty_writeback_bytes"),
        MIB,
        "exactly A's megabyte is written back at eviction"
    );
    assert_eq!(eng.core.metrics.counter("pfs.bytes_written"), MIB);

    // Closing the file purges B's parked dirty array the same way.
    let cfut = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(cfut));
    eng.run();
    assert!(eng.future_done(cfut));
    assert_eq!(eng.core.metrics.counter("ckio.store.dirty_writeback_bytes"), 2 * MIB);
    assert_eq!(eng.core.metrics.counter("pfs.bytes_written"), 2 * MIB);
    assert_service_clean(&eng, &io);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

/// Transient PFS write faults: the flush barrier still drains every
/// byte durably (retries absorb the faults, nothing degrades) and the
/// close callback fires exactly once.
#[test]
fn flush_barrier_and_exactly_once_close_under_write_faults() {
    let size = 2 * MIB;
    let cfg = ServiceConfig {
        max_inflight_reads: Some(4),
        data_plane_shards: Some(1),
        retry: Some(RetryPolicy::default()),
        ..Default::default()
    };
    let pfs = PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        faults: FaultPlan { transient_p: 0.3, ..Default::default() },
        ..PfsConfig::default()
    };
    let (mut eng, file, io) = write_engine(size, cfg, pfs);
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);

    // Small stripes -> 32 write RPCs -> transient faults at p=0.3 are
    // statistically certain to hit at least one of them.
    let wopts = WriteOptions { stripe_bytes: 64 << 10, ..Default::default() };
    let ws = start_write(&mut eng, &io, file, 0, size, SessionOptions::default(), wopts);
    put_all(&mut eng, &io, &ws, 0, size);
    let ffut = eng.future(1);
    io.flush_write_driver(&mut eng, ws.id, Callback::Future(ffut));
    eng.run();
    assert!(eng.future_done(ffut), "flush barrier never completed under faults");
    let o = close_write(&mut eng, &io, ws.id);
    assert_eq!(o.written_bytes, size, "transient faults must clear on retry");
    assert_eq!(eng.core.metrics.counter("ckio.write.degraded_bytes"), 0);
    assert!(
        eng.core.metrics.counter("ckio.retry.attempts") > 0,
        "p=0.3 over 32 write RPCs must retry at least once"
    );
    assert_eq!(
        eng.core.metrics.counter("pfs.bytes_written"),
        size,
        "retries must not double-count durable bytes"
    );

    assert_service_clean(&eng, &io);
    let cfut = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(cfut));
    eng.run();
    assert!(eng.future_done(cfut));
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

/// An Interactive writer and a Bulk reader contend on the same governed
/// shard: both classes register with the admission governor, the tight
/// cap throttles, and both sides finish verified with clean quiescence.
#[test]
fn mixed_reader_writer_qos_contention_on_one_shard() {
    let size = 2 * MIB;
    let cfg = ServiceConfig {
        max_inflight_reads: Some(2),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let (mut eng, file, io) = write_engine(size, cfg, clean_pfs());
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);

    // Interactive writer over the second half, small stripes so the
    // write side alone outnumbers the cap.
    let wopts = WriteOptions { stripe_bytes: 64 << 10, ..Default::default() };
    let ws = start_write(&mut eng, &io, file, MIB, MIB, SessionOptions::interactive(), wopts);

    // Start the Bulk reader's session and scatter the writes WITHOUT
    // quiescing in between: the reader's greedy staging reads and the
    // writer's extent flushes race through the one governed shard in
    // the same scheduling window.
    let rfut = eng.future(1);
    io.start_session_driver(
        &mut eng,
        file,
        0,
        MIB,
        SessionOptions::default(),
        Callback::Future(rfut),
    );
    let npes = eng.core.topo.npes();
    let wfut = eng.future((MIB / PIECE) as u32);
    let mut o = MIB;
    let mut i = 0u32;
    while o < 2 * MIB {
        io.write_driver(&mut eng, i % npes, &ws, o, PIECE, Callback::Future(wfut));
        o += PIECE;
        i += 1;
    }
    eng.run();
    assert!(eng.future_done(rfut) && eng.future_done(wfut));
    let rs = {
        let (_, mut p) = eng.take_future(rfut).pop().unwrap();
        p.take::<Session>()
    };
    let dfut = eng.future(1);
    io.read_driver(&mut eng, 0, &rs, 0, MIB, Callback::Future(dfut));
    eng.run();
    assert!(eng.future_done(dfut));
    let (_, mut p) = eng.take_future(dfut).pop().unwrap();
    let r = p.take::<ReadResult>();
    let bytes = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
    assert_eq!(pattern::verify(file, 0, bytes), None, "reader corrupted under contention");

    let shard = io.shard(&eng, 0);
    assert!(
        shard.class_registrations(QosClass::Interactive) > 0,
        "the writer must register its class with the shard"
    );
    assert!(
        shard.class_registrations(QosClass::Bulk) > 0,
        "the reader must register its class with the shard"
    );
    assert!(
        eng.core.metrics.counter("ckio.governor.throttled") > 0,
        "a cap of 2 under mixed demand must throttle"
    );

    let ffut = eng.future(1);
    io.flush_write_driver(&mut eng, ws.id, Callback::Future(ffut));
    eng.run();
    assert!(eng.future_done(ffut));
    let o = close_write(&mut eng, &io, ws.id);
    assert_eq!(o.written_bytes, MIB);
    let cfut = eng.future(1);
    io.close_session_driver(&mut eng, rs.id, Callback::Future(cfut));
    eng.run();
    assert!(eng.future_done(cfut));

    assert_service_clean(&eng, &io);
    let xfut = eng.future(1);
    io.close_file_driver(&mut eng, file, Callback::Future(xfut));
    eng.run();
    assert!(eng.future_done(xfut));
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}
