//! Deterministic chaos suite (PR 8): the fault-injected PFS against the
//! retry plane, schedule by schedule.
//!
//! * **Straggler-only** — slow OSTs stretch service but nothing fails:
//!   every byte arrives verified, with zero retries, timeouts, or
//!   degraded spans (the generous default deadline must not fire on
//!   healthy-but-slow reads).
//! * **Transient EIO** — errors clear on retry: with a sane attempt
//!   budget the session still serves every byte verified, and the
//!   outcome's retry counters match the engine-wide metrics exactly.
//! * **Persistent EIO** — every extent re-fails deterministically: the
//!   budget exhausts with *exact* counts (`(max_attempts - 1) × slots`
//!   retries, one give-up per slot) and every byte degrades to a
//!   modeled chunk, delivered exactly once.
//! * **Short reads** — routed through the same retry machine as errors,
//!   with the same exact accounting.
//! * **Mixed persistence** — the extent hash picks survivors: surviving
//!   spans are byte-verified, degraded spans are modeled, and the
//!   outcome equations hold whatever the split.
//! * **Deadline timeouts** — a deadline below the service floor forces
//!   every attempt through the abandon→ticket-return→backoff path, with
//!   exact timeout/retry/late accounting and no governor leak.
//! * **Hedged reads** — duplicates race slow originals; every slot
//!   settles exactly once, clean, with zero retries charged.
//! * **Owner-death reclaim** (satellite regression) — a session closed
//!   with governed reads in flight returns its tickets in bulk
//!   (`ckio.governor.reclaimed`), leaving no inflight count or queued
//!   demand behind.
//!
//! Every run is virtual-clock and seeded: the same schedule replays the
//! same faults, so the exact-count assertions are stable.

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::time::Time;
use ckio::amt::topology::Pe;
use ckio::ckio::{
    CkIo, FileOptions, ReadResult, RetryPolicy, ServiceConfig, Session, SessionId,
    SessionOptions, SessionOutcome,
};
use ckio::harness::experiments::assert_service_clean;
use ckio::impl_chare_any;
use ckio::metrics::keys;
use ckio::pfs::{pattern, FaultPlan, FileId, PfsConfig, StragglerSpec};

const KIB: u64 = 1 << 10;
/// Splinter size every schedule uses: reads issued in splinter-aligned
/// pieces map 1:1 onto slots, so per-piece byte presence mirrors
/// per-slot give-up decisions exactly.
const SPLINTER: u64 = 16 * KIB;
const SEED: u64 = 0xC4A05;

/// A verified-data PFS carrying `faults`, quiet (no service noise) so
/// the exact-count assertions replay bit for bit.
fn chaos_pfs(faults: FaultPlan) -> PfsConfig {
    PfsConfig { materialize: true, noise_sigma: 0.0, faults, ..PfsConfig::default() }
}

/// Boot a governed service with the retry plane armed: fixed cap 4 on a
/// single data-plane shard (one governor owns every ticket, so the
/// leak checks see the whole admission state).
fn chaos_engine(pfs: PfsConfig, file_size: u64, policy: RetryPolicy) -> (Engine, FileId, CkIo) {
    let mut eng = Engine::new(EngineConfig::sim(2, 2).with_seed(SEED)).with_sim_pfs(pfs);
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let cfg = ServiceConfig {
        max_inflight_reads: Some(4),
        data_plane_shards: Some(1),
        retry: Some(policy),
        ..Default::default()
    };
    let io = CkIo::boot_with(&mut eng, cfg).expect("valid ServiceConfig");
    (eng, file, io)
}

fn open_file(eng: &mut Engine, io: &CkIo, file: FileId, size: u64) {
    let fut = eng.future(1);
    io.open_driver(eng, file, size, FileOptions::with_readers(2), Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "open never completed");
}

fn start_session(eng: &mut Engine, io: &CkIo, file: FileId, bytes: u64) -> Session {
    let fut = eng.future(1);
    let sopts = SessionOptions { splinter_bytes: Some(SPLINTER), ..Default::default() };
    io.start_session_driver(eng, file, 0, bytes, sopts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session never became ready");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    p.take::<Session>()
}

/// Close the session and return the structured [`SessionOutcome`] the
/// close callback now carries (PR 8) — delivered exactly once.
fn close_session(eng: &mut Engine, io: &CkIo, sid: SessionId) -> SessionOutcome {
    let fut = eng.future(1);
    io.close_session_driver(eng, sid, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session close never completed");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    let o: SessionOutcome = p.take();
    assert_eq!(o.session, sid, "outcome must name the closed session");
    o
}

fn close_file(eng: &mut Engine, io: &CkIo, file: FileId) {
    let fut = eng.future(1);
    io.close_file_driver(eng, file, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "file close never completed");
}

/// Read `[0, total)` in splinter-aligned pieces through PE 0's manager;
/// every read callback must fire exactly once.
fn read_all(eng: &mut Engine, io: &CkIo, s: &Session, total: u64) -> Vec<ReadResult> {
    assert_eq!(total % SPLINTER, 0, "chaos reads must stay slot-aligned");
    let n = (total / SPLINTER) as u32;
    let fut = eng.future(n);
    for i in 0..n as u64 {
        io.read_driver(eng, 0, s, i * SPLINTER, SPLINTER, Callback::Future(fut));
    }
    eng.run();
    assert!(eng.future_done(fut), "a read callback never fired");
    let results: Vec<ReadResult> =
        eng.take_future(fut).into_iter().map(|(_, mut p)| p.take::<ReadResult>()).collect();
    assert_eq!(results.len(), n as usize, "every read completes exactly once");
    results
}

/// Partition delivered reads into (served, degraded) byte counts,
/// byte-verifying every surviving span against the file pattern. A
/// materialized run answers clean reads with real bytes and gave-up
/// spans with modeled chunks, so presence-of-bytes *is* the split.
fn split_and_verify(file: FileId, results: &[ReadResult]) -> (u64, u64) {
    let (mut served, mut degraded) = (0u64, 0u64);
    for r in results {
        match r.chunk.bytes.as_ref() {
            Some(b) => {
                assert_eq!(b.len() as u64, r.len, "truncated piece at {}", r.offset);
                assert_eq!(
                    pattern::verify(file, r.offset, b),
                    None,
                    "data corruption at offset {}",
                    r.offset
                );
                served += r.len;
            }
            None => degraded += r.len,
        }
    }
    (served, degraded)
}

// ---------------------------------------------------------------------
// 1. Straggler-only: slow is not failed
// ---------------------------------------------------------------------

#[test]
fn straggler_only_schedule_serves_every_byte_with_zero_retries() {
    let size = 256 * KIB;
    // Two OSTs, both straggling 8× for the whole run, striped so every
    // RPC lands on a straggler. The default 200 ms deadline is far above
    // the stretched service time: nothing may time out or retry.
    let pfs = PfsConfig {
        ost_count: 2,
        stripe_count: 2,
        stripe_size: 32 * KIB,
        faults: FaultPlan {
            stragglers: vec![
                StragglerSpec { ost: 0, multiplier: 8.0, from: 0, until: Time::MAX },
                StragglerSpec { ost: 1, multiplier: 8.0, from: 0, until: Time::MAX },
            ],
            ..Default::default()
        },
        ..chaos_pfs(FaultPlan::default())
    };
    let (mut eng, file, io) = chaos_engine(pfs, size, RetryPolicy::default());
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!((served, degraded), (size, 0), "slow reads must still deliver data");

    let o = close_session(&mut eng, &io, s.id);
    assert!(o.is_clean(), "straggler-only outcome must be clean: {o:?}");
    assert_eq!(o.served_bytes, size);
    assert_eq!((o.retries, o.hedges, o.gave_up_spans), (0, 0, 0));

    let m = &eng.core.metrics;
    assert!(m.counter(keys::FAULT_STRAGGLER) > 0, "the stragglers must have been hit");
    assert_eq!(m.counter(keys::RETRY_ATTEMPTS), 0, "no retry on a healthy-but-slow read");
    assert_eq!(m.counter(keys::RETRY_TIMEOUTS), 0, "the deadline must not fire");
    assert_eq!(m.counter(keys::SESSION_DEGRADED), 0);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 2. Transient EIO: retries clear it, bytes stay verified
// ---------------------------------------------------------------------

#[test]
fn transient_faults_clear_on_retry_and_bytes_stay_verified() {
    let size = 512 * KIB; // 32 slots: plenty of fault draws at p = 0.3
    let pfs = chaos_pfs(FaultPlan { transient_p: 0.3, ..Default::default() });
    // A deep attempt budget: at p = 0.3 a slot exhausting 12 attempts
    // has probability ~5e-7 — the seeded schedule serves everything.
    let policy = RetryPolicy { max_attempts: 12, ..RetryPolicy::default() };
    let (mut eng, file, io) = chaos_engine(pfs, size, policy);
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!(served + degraded, size, "exactly-once byte accounting");
    assert_eq!(degraded, 0, "transient faults must clear within the budget");

    let o = close_session(&mut eng, &io, s.id);
    assert!(o.is_clean(), "transient outcome must be clean: {o:?}");
    assert_eq!(o.served_bytes, size);
    assert!(o.retries > 0, "p = 0.3 over 32 first attempts must fault somewhere");

    // The session outcome and the engine-wide metrics are two views of
    // the same counters: they must agree exactly.
    let m = &eng.core.metrics;
    assert!(m.counter(keys::FAULT_TRANSIENT) > 0);
    assert_eq!(m.counter(keys::RETRY_ATTEMPTS), o.retries);
    assert_eq!(m.counter(keys::RETRY_GAVE_UP), 0);
    assert_eq!(m.counter(keys::SESSION_DEGRADED), 0);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 3. Persistent EIO at p = 1.0: exact exhaustion accounting
// ---------------------------------------------------------------------

#[test]
fn persistent_faults_exhaust_the_budget_with_exact_counts() {
    let size = 128 * KIB; // 8 slots over 2 buffer chares
    let slots = size / SPLINTER;
    let pfs = chaos_pfs(FaultPlan { persistent_p: 1.0, ..Default::default() });
    let policy = RetryPolicy::default(); // max_attempts = 4
    let (mut eng, file, io) = chaos_engine(pfs, size, policy);
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!((served, degraded), (0, size), "every extent is permanently bad");

    let o = close_session(&mut eng, &io, s.id);
    assert!(!o.is_clean());
    assert_eq!(o.served_bytes, 0);
    assert_eq!(o.degraded_bytes, size, "every byte degrades, delivered exactly once");
    assert_eq!(o.gave_up_spans, slots, "one give-up per slot");
    assert_eq!(
        o.retries,
        (policy.max_attempts as u64 - 1) * slots,
        "each slot re-issues exactly max_attempts - 1 times"
    );
    assert_eq!(o.hedges, 0);

    let m = &eng.core.metrics;
    assert_eq!(m.counter(keys::RETRY_ATTEMPTS), o.retries);
    assert_eq!(m.counter(keys::RETRY_GAVE_UP), slots);
    assert_eq!(
        m.counter(keys::FAULT_PERSISTENT),
        policy.max_attempts as u64 * slots,
        "every attempt of every slot surfaces the persistent fault"
    );
    assert_eq!(m.counter(keys::RETRY_TIMEOUTS), 0, "failures completed, nothing timed out");
    assert_eq!(m.counter(keys::SESSION_DEGRADED), size);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 4. Short reads ride the same retry machine as errors
// ---------------------------------------------------------------------

#[test]
fn short_reads_retry_and_exhaust_exactly_like_errors() {
    let size = 128 * KIB;
    let slots = size / SPLINTER;
    let pfs = chaos_pfs(FaultPlan { short_p: 1.0, ..Default::default() });
    let policy = RetryPolicy::default();
    let (mut eng, file, io) = chaos_engine(pfs, size, policy);
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!((served, degraded), (0, size), "a permanent short never fills its slot");

    let o = close_session(&mut eng, &io, s.id);
    assert_eq!(o.degraded_bytes, size);
    assert_eq!(o.gave_up_spans, slots);
    assert_eq!(o.retries, (policy.max_attempts as u64 - 1) * slots);

    // A short with a useless (< 1 byte) prefix is surfaced as a plain
    // transient error; together the two must cover every attempt.
    let m = &eng.core.metrics;
    assert!(m.counter(keys::FAULT_SHORT) > 0, "p = 1.0 must produce short completions");
    assert_eq!(
        m.counter(keys::FAULT_SHORT) + m.counter(keys::FAULT_TRANSIENT),
        policy.max_attempts as u64 * slots
    );
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 5. Mixed persistence: survivors verified, equations hold either way
// ---------------------------------------------------------------------

#[test]
fn mixed_persistence_verifies_surviving_spans() {
    let size = 256 * KIB; // 16 slots; the extent hash picks the victims
    let pfs = chaos_pfs(FaultPlan { persistent_p: 0.35, ..Default::default() });
    let policy = RetryPolicy::default();
    let (mut eng, file, io) = chaos_engine(pfs, size, policy);
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    // Surviving spans carry verified bytes; bad extents degrade. The
    // split itself is seed-determined, but the accounting identities
    // hold for any split.
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!(served + degraded, size, "exactly-once byte accounting");

    let o = close_session(&mut eng, &io, s.id);
    assert_eq!(o.served_bytes, served, "outcome and delivered chunks must agree");
    assert_eq!(o.degraded_bytes, degraded);
    assert_eq!(o.degraded_bytes, o.gave_up_spans * SPLINTER, "degradation is whole slots");
    assert_eq!(
        o.retries,
        (policy.max_attempts as u64 - 1) * o.gave_up_spans,
        "persistent faults retry to exhaustion; healthy extents never retry"
    );
    let m = &eng.core.metrics;
    assert_eq!(m.counter(keys::FAULT_PERSISTENT), policy.max_attempts as u64 * o.gave_up_spans);
    assert_eq!(m.counter(keys::SESSION_DEGRADED), degraded);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 6. Deadline timeouts: abandon, return the ticket, back off, re-issue
// ---------------------------------------------------------------------

#[test]
fn deadline_timeouts_abandon_and_reissue_with_exact_accounting() {
    let size = 128 * KIB;
    let slots = size / SPLINTER;
    // No PFS faults at all — the deadline is the only adversary. 1 µs is
    // far below the 300 µs RPC overhead, so *every* attempt times out
    // before its (healthy) completion lands.
    let pfs = chaos_pfs(FaultPlan::default());
    let policy = RetryPolicy {
        max_attempts: 3,
        default_deadline_ns: 1_000,
        ..RetryPolicy::default()
    };
    let (mut eng, file, io) = chaos_engine(pfs, size, policy);
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!((served, degraded), (0, size), "abandoned attempts never deliver");

    let o = close_session(&mut eng, &io, s.id);
    assert_eq!(o.gave_up_spans, slots);
    assert_eq!(o.degraded_bytes, size);
    assert_eq!(o.retries, (policy.max_attempts as u64 - 1) * slots);

    let m = &eng.core.metrics;
    assert_eq!(
        m.counter(keys::RETRY_TIMEOUTS),
        policy.max_attempts as u64 * slots,
        "every attempt's deadline expires"
    );
    assert_eq!(
        m.counter(keys::RETRY_LATE),
        policy.max_attempts as u64 * slots,
        "every abandoned attempt's completion arrives late and is dropped"
    );
    assert_eq!(m.counter(keys::FAULT_TRANSIENT), 0, "the PFS itself was healthy");
    close_file(&mut eng, &io, file);
    // The decisive leak check: every abandoned attempt returned its
    // ticket at timeout, every late completion returned nothing.
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 7. Hedged reads: duplicates race, slots settle exactly once
// ---------------------------------------------------------------------

#[test]
fn hedged_reads_settle_every_slot_exactly_once() {
    let size = 128 * KIB;
    let slots = size / SPLINTER;
    let pfs = chaos_pfs(FaultPlan::default());
    // 50 µs deadline under a ~300 µs service floor: every first attempt
    // goes overdue, stays live, and races a hedged duplicate.
    let policy =
        RetryPolicy { default_deadline_ns: 50_000, ..RetryPolicy::default() }.with_hedging();
    let (mut eng, file, io) = chaos_engine(pfs, size, policy);
    open_file(&mut eng, &io, file, size);
    let s = start_session(&mut eng, &io, file, size);
    let results = read_all(&mut eng, &io, &s, size);
    let (served, degraded) = split_and_verify(file, &results);
    assert_eq!((served, degraded), (size, 0), "hedging must not degrade a healthy read");

    let o = close_session(&mut eng, &io, s.id);
    assert!(o.is_clean(), "hedged outcome must be clean: {o:?}");
    assert_eq!(o.served_bytes, size);
    assert!(o.hedges >= slots, "every slot's first attempt goes overdue and hedges");
    assert_eq!(o.retries, 0, "hedges are duplicates, never charged as retries");
    assert_eq!(o.gave_up_spans, 0);

    let m = &eng.core.metrics;
    assert_eq!(m.counter(keys::RETRY_HEDGES), o.hedges);
    assert!(
        m.counter(keys::RETRY_TIMEOUTS) >= o.hedges,
        "every hedge was armed by an expired deadline"
    );
    assert_eq!(m.counter(keys::RETRY_LATE), 0, "hedge losers complete live, not late");
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 8. Owner-death reclaim (satellite regression): tickets return in bulk
// ---------------------------------------------------------------------

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;
const EP_CLOSED: Ep = 5;
const EP_FCLOSED: Ep = 6;

/// Issues reads and the session close in the same handler, so the drop
/// lands while the buffers' governed greedy reads (and their retry
/// deadlines) are still in flight — the owner-death path.
struct RetryRacyCloser {
    io: CkIo,
    file: FileId,
    size: u64,
    n_reads: u32,
    reads_seen: u32,
    outcome: Option<SessionOutcome>,
    file_closed: bool,
    done: Callback,
}

impl RetryRacyCloser {
    fn maybe_done(&mut self, ctx: &mut Ctx<'_>) {
        if self.file_closed && self.reads_seen == self.n_reads {
            let done = self.done.clone();
            ctx.fire(done, Payload::empty());
        }
    }
}

impl Chare for RetryRacyCloser {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.size);
                io.open(
                    ctx,
                    file,
                    size,
                    FileOptions::with_readers(2),
                    Callback::to_chare(me, EP_OPENED),
                );
            }
            EP_OPENED => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.size);
                io.start_read_session(
                    ctx,
                    file,
                    0,
                    size,
                    SessionOptions { splinter_bytes: Some(SPLINTER), ..Default::default() },
                    Callback::to_chare(me, EP_READY),
                );
            }
            EP_READY => {
                let s: Session = msg.take();
                let me = ctx.me();
                let io = self.io;
                // Reads and the close depart together: the drop reaches
                // the buffers while their governed greedy reads are
                // mid-service, deadlines armed.
                let per = self.size / self.n_reads as u64;
                for i in 0..self.n_reads as u64 {
                    io.read(ctx, &s, i * per, per, Callback::to_chare(me, EP_DATA));
                }
                io.close_read_session(ctx, s.id, Callback::to_chare(me, EP_CLOSED));
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                assert!(r.len > 0);
                self.reads_seen += 1;
                assert!(self.reads_seen <= self.n_reads, "a read callback fired twice");
                self.maybe_done(ctx);
            }
            EP_CLOSED => {
                let o: SessionOutcome = msg.take();
                assert!(self.outcome.is_none(), "close callback fired twice");
                self.outcome = Some(o);
                let me = ctx.me();
                let (io, file) = (self.io, self.file);
                io.close(ctx, file, Callback::to_chare(me, EP_FCLOSED));
            }
            EP_FCLOSED => {
                self.file_closed = true;
                self.maybe_done(ctx);
            }
            other => panic!("RetryRacyCloser: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

#[test]
fn closing_mid_flight_reclaims_tickets_from_the_dead_owner() {
    let size = 1024 * KIB;
    let n_reads = 8u32;
    let pfs = chaos_pfs(FaultPlan::default());
    let (mut eng, file, io) = chaos_engine(pfs, size, RetryPolicy::default());
    let fut = eng.future(1);
    let c = eng.create_singleton(Pe(1), RetryRacyCloser {
        io,
        file,
        size,
        n_reads,
        reads_seen: 0,
        outcome: None,
        file_closed: false,
        done: Callback::Future(fut),
    });
    eng.inject_signal(c, EP_GO);
    eng.run(); // must quiesce: late timers and completions all no-op
    assert!(eng.future_done(fut), "reads or closes never completed");

    let closer: &RetryRacyCloser = eng.chare(c);
    assert_eq!(closer.reads_seen, n_reads, "every racing read completes exactly once");
    let o = closer.outcome.expect("the racing close must deliver its outcome");
    assert!(
        o.served_bytes + o.degraded_bytes <= size,
        "the outcome never reports more bytes than the session owned"
    );

    // The regression itself: the drop found governed reads in flight and
    // reclaimed their tickets in bulk — and afterwards the governor
    // holds no inflight count, no queued demand, nothing.
    assert!(
        eng.core.metrics.counter(keys::GOV_RECLAIMED) > 0,
        "teardown mid-flight must take the bulk-reclaim path"
    );
    assert_service_clean(&eng, &io);
    assert_eq!(io.cached_buffer_arrays(&eng), 0);
}
