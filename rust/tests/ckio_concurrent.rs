//! Concurrent multi-session lifecycle tests (PR 1):
//!
//! * teardown race — closing a session with reads in flight completes
//!   every outstanding `read` callback exactly once (regression for the
//!   old `EP_BUF_DROP` silently clearing `pending`),
//! * verified-mode end-to-end run with splintered reads crossing buffer
//!   boundaries under concurrent sessions, with leak checks on the
//!   assembler/manager/director tables after every close,
//! * parked-buffer reuse: a repeated session over the same file is
//!   served from resident data with zero new file-system traffic,
//! * concurrent opens of the same file are refcounted.

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef, CollectionId};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::topology::{Pe, Placement};
use ckio::ckio::director::Director;
use ckio::ckio::{CkIo, FileOptions, ReadResult, ServiceConfig, Session, SessionOptions};
use ckio::harness::experiments::assert_service_clean;
use ckio::impl_chare_any;
use ckio::pfs::{pattern, FileId, PfsConfig};

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;
const EP_CLOSED: Ep = 5;
const EP_FCLOSED: Ep = 6;
const EP_SESSION_FWD: Ep = 7;
const EP_SLICE_DONE: Ep = 8;

// ---------------------------------------------------------------------
// 1. Teardown race: close with reads in flight
// ---------------------------------------------------------------------

/// Issues `n_reads` split-phase reads and a `closeReadSession` in the
/// same handler, so the close races every read through the manager →
/// assembler → buffer pipeline. Every read callback must fire exactly
/// once (data or NACK), and the close must complete.
struct RacyCloser {
    io: CkIo,
    file: FileId,
    size: u64,
    n_reads: u32,
    reads_seen: u32,
    closed: bool,
    done: Callback,
}

impl RacyCloser {
    fn maybe_done(&mut self, ctx: &mut Ctx<'_>) {
        if self.closed && self.reads_seen == self.n_reads {
            let done = self.done.clone();
            ctx.fire(done, Payload::empty());
        }
    }
}

impl Chare for RacyCloser {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.size);
                io.open(
                    ctx,
                    file,
                    size,
                    FileOptions::with_readers(4),
                    Callback::to_chare(me, EP_OPENED),
                );
            }
            EP_OPENED => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.size);
                io.start_read_session(
                    ctx,
                    file,
                    0,
                    size,
                    SessionOptions::default(),
                    Callback::to_chare(me, EP_READY),
                );
            }
            EP_READY => {
                let s: Session = msg.take();
                let me = ctx.me();
                let io = self.io;
                // Reads and close depart together: the buffers' greedy
                // prefetch (256 MiB spans) is certainly still in flight,
                // and so are these fetches when the drop lands.
                let per = self.size / self.n_reads as u64;
                for i in 0..self.n_reads as u64 {
                    io.read(ctx, &s, i * per, per, Callback::to_chare(me, EP_DATA));
                }
                io.close_read_session(ctx, s.id, Callback::to_chare(me, EP_CLOSED));
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                assert!(r.len > 0);
                self.reads_seen += 1;
                assert!(
                    self.reads_seen <= self.n_reads,
                    "a read callback fired more than once"
                );
                self.maybe_done(ctx);
            }
            EP_CLOSED => {
                assert!(!self.closed, "close callback fired twice");
                self.closed = true;
                self.maybe_done(ctx);
            }
            other => panic!("RacyCloser: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

#[test]
fn close_with_reads_in_flight_completes_every_callback_exactly_once() {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(1 << 30);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(1);
    let c = eng.create_singleton(Pe(1), RacyCloser {
        io,
        file,
        size: 1 << 30,
        n_reads: 8,
        reads_seen: 0,
        closed: false,
        done: Callback::Future(fut),
    });
    eng.inject_signal(c, EP_GO);
    eng.run(); // must quiesce: no stranded assemblies, no panics
    assert!(eng.future_done(fut), "reads or close never completed");
    let closer: &RacyCloser = eng.chare(c);
    assert_eq!(closer.reads_seen, 8, "every outstanding read completes exactly once");
    assert!(closer.closed);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 2. Verified concurrent sessions, splintered reads across buffer spans
// ---------------------------------------------------------------------

/// One client of a verified session: reads its slice, checks every byte
/// against the deterministic file pattern, reports to the leader; the
/// leader closes the session, then the file.
struct VerifyClient {
    io: CkIo,
    file: FileId,
    size: u64,
    n_peers: u32,
    peers: CollectionId,
    fopts: FileOptions,
    sopts: SessionOptions,
    my_offset: u64,
    my_len: u64,
    session: Option<Session>,
    slices_done: u32,
    /// Whether the leader also drops its file refcount after the session
    /// closes (off when a driver keeps the file open across sessions).
    close_file: bool,
    done: Callback,
}

impl Chare for VerifyClient {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                let me = ctx.me();
                let (io, file, size, fopts) =
                    (self.io, self.file, self.size, self.fopts.clone());
                io.open(ctx, file, size, fopts, Callback::to_chare(me, EP_OPENED));
            }
            EP_OPENED => {
                let me = ctx.me();
                let (io, file, size, sopts) =
                    (self.io, self.file, self.size, self.sopts.clone());
                io.start_read_session(
                    ctx,
                    file,
                    0,
                    size,
                    sopts,
                    Callback::to_chare(me, EP_READY),
                );
            }
            EP_READY | EP_SESSION_FWD => {
                let s: Session = msg.take();
                if msg.ep == EP_READY {
                    for j in 1..self.n_peers {
                        ctx.send(ChareRef::new(self.peers, j), EP_SESSION_FWD, s);
                    }
                }
                self.session = Some(s);
                let me = ctx.me();
                let (io, off, len) = (self.io, self.my_offset, self.my_len);
                io.read(ctx, &s, off, len, Callback::to_chare(me, EP_DATA));
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                assert_eq!(r.len, self.my_len);
                let bytes = r.chunk.bytes.as_ref().expect("materialized run");
                assert_eq!(bytes.len() as u64, r.len);
                assert_eq!(
                    pattern::verify(self.file, r.offset, bytes),
                    None,
                    "corrupt read at {} in session {:?}",
                    r.offset,
                    r.session
                );
                ctx.send(ChareRef::new(self.peers, 0), EP_SLICE_DONE, ());
            }
            EP_SLICE_DONE => {
                self.slices_done += 1;
                if self.slices_done == self.n_peers {
                    let sid = self.session.as_ref().unwrap().id;
                    let me = ctx.me();
                    let io = self.io;
                    io.close_read_session(ctx, sid, Callback::to_chare(me, EP_CLOSED));
                }
            }
            EP_CLOSED => {
                if self.close_file {
                    let me = ctx.me();
                    let (io, file) = (self.io, self.file);
                    io.close(ctx, file, Callback::to_chare(me, EP_FCLOSED));
                } else {
                    let done = self.done.clone();
                    ctx.fire(done, Payload::empty());
                }
            }
            EP_FCLOSED => {
                let done = self.done.clone();
                ctx.fire(done, Payload::empty());
            }
            other => panic!("VerifyClient: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

#[allow(clippy::too_many_arguments)]
fn spawn_verified_session(
    eng: &mut Engine,
    io: CkIo,
    file: FileId,
    size: u64,
    nclients: u32,
    fopts: FileOptions,
    sopts: SessionOptions,
    close_file: bool,
    done: Callback,
) -> ChareRef {
    let per = size / nclients as u64;
    let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| {
        let lo = i as u64 * per;
        let hi = if i == nclients - 1 { size } else { lo + per };
        VerifyClient {
            io,
            file,
            size,
            n_peers: nclients,
            peers: CollectionId(u32::MAX),
            fopts: fopts.clone(),
            sopts: sopts.clone(),
            my_offset: lo,
            my_len: hi - lo,
            session: None,
            slices_done: 0,
            close_file,
            done: done.clone(),
        }
    });
    for i in 0..nclients {
        eng.chare_mut::<VerifyClient>(ChareRef::new(cid, i)).peers = cid;
    }
    ChareRef::new(cid, 0)
}

#[test]
fn concurrent_verified_sessions_with_boundary_crossing_splinters() {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let size: u64 = 3 << 20;
    // Two concurrent sessions over two distinct files, plus a third over
    // the first file (same-file concurrency): 4 buffers each => 768 KiB
    // spans; 3 clients each => 1 MiB slices, so every middle read crosses
    // a buffer-chare boundary; 64 KiB splinters keep pieces partial.
    let file_a = eng.core.sim_pfs_mut().create_file(size);
    let file_b = eng.core.sim_pfs_mut().create_file(size);
    let io = CkIo::boot(&mut eng);
    let fopts = FileOptions::with_readers(4);
    let sopts = SessionOptions { splinter_bytes: Some(64 << 10), ..Default::default() };
    let fut = eng.future(3 * 3); // 3 sessions x 3 clients
    let leaders = [
        spawn_verified_session(
            &mut eng,
            io,
            file_a,
            size,
            3,
            fopts.clone(),
            sopts.clone(),
            true,
            Callback::Future(fut),
        ),
        spawn_verified_session(
            &mut eng,
            io,
            file_b,
            size,
            3,
            fopts.clone(),
            sopts.clone(),
            true,
            Callback::Future(fut),
        ),
        spawn_verified_session(
            &mut eng,
            io,
            file_a,
            size,
            3,
            fopts,
            sopts,
            true,
            Callback::Future(fut),
        ),
    ];
    for l in leaders {
        eng.inject_signal(l, EP_GO);
    }
    eng.run();
    assert!(eng.future_done(fut), "not every client finished");
    // All 3 sessions' bytes were delivered, with verified contents.
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 3 * size);
    assert_eq!(eng.core.metrics.counter("ckio.sessions"), 3);
    // No session/assembly/pending residue and no leaked file refs.
    assert_service_clean(&eng, &io);
    let director: &Director = eng.chare(io.director);
    assert_eq!(director.open_files(), 0, "refcounted closes should empty the file table");
}

// ---------------------------------------------------------------------
// 3. Parked-buffer reuse across back-to-back sessions
// ---------------------------------------------------------------------

/// Runs two sequential verified sessions over the same file with
/// `reuse_buffers` on; the second must be served entirely from the
/// parked array (zero new PFS traffic).
#[test]
fn repeated_session_with_reuse_reads_the_file_once() {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let size: u64 = 2 << 20;
    let file = eng.core.sim_pfs_mut().create_file(size);
    let io = CkIo::boot(&mut eng);
    let fopts = FileOptions::with_readers(4);
    let sopts = SessionOptions { reuse_buffers: true, ..Default::default() };

    // The driver holds the file open across both sessions (a refcount of
    // its own), so the parked array survives the gap between them.
    io.open_driver(&mut eng, file, size, fopts.clone(), Callback::Ignore);

    // Session 1 (does not drop the file ref).
    let fut1 = eng.future(2);
    let l1 = spawn_verified_session(
        &mut eng,
        io,
        file,
        size,
        2,
        fopts.clone(),
        sopts.clone(),
        false,
        Callback::Future(fut1),
    );
    eng.inject_signal(l1, EP_GO);
    eng.run();
    assert!(eng.future_done(fut1));
    let bytes_after_first = eng.core.metrics.counter("pfs.bytes_read");
    assert!(bytes_after_first >= size, "first session must actually read the file");
    assert_eq!(io.cached_buffer_arrays(&eng), 1, "close must park the array");

    // Session 2, identical shape: the parked array is rebound.
    let fut2 = eng.future(2);
    let l2 = spawn_verified_session(
        &mut eng,
        io,
        file,
        size,
        2,
        fopts,
        sopts,
        false,
        Callback::Future(fut2),
    );
    eng.inject_signal(l2, EP_GO);
    eng.run();
    assert!(eng.future_done(fut2));
    assert_eq!(
        eng.core.metrics.counter("pfs.bytes_read"),
        bytes_after_first,
        "second session must be served from the parked buffers"
    );
    assert_eq!(eng.core.metrics.counter("ckio.buffer_reuse"), 1);
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 2 * size);
    assert_service_clean(&eng, &io);

    // Dropping every file ref (sessions dropped theirs via `open` only;
    // the two session opens and the driver's add up to 3 refs, of which
    // the sessions never closed — so three driver-side closes) finally
    // purges the parked array and empties the file table.
    let cfut = eng.future(3);
    for _ in 0..3 {
        io.close_file_driver(&mut eng, file, Callback::Future(cfut));
    }
    eng.run();
    assert!(eng.future_done(cfut));
    assert_eq!(io.cached_buffer_arrays(&eng), 0, "final file close must purge the cache");
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

// ---------------------------------------------------------------------
// 4. Admission governor: cap = 1 fully sequences two sessions' PFS reads
// ---------------------------------------------------------------------

/// With the in-flight cap set to 1 and the data plane pinned to a single
/// shard (`data_plane_shards: 1` — the PR 2 cluster-wide semantics; the
/// per-shard behavior with distinct files on distinct shards is covered
/// in `ckio_shard.rs`), two concurrent verified sessions over *distinct*
/// files (so the span store cannot dedup any read away) are fully
/// sequenced at the PFS — the model never observes more than one read in
/// flight — while every read callback still fires exactly once with
/// verified contents.
#[test]
fn governor_cap_one_sequences_two_sessions_and_loses_no_callback() {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let size: u64 = 2 << 20;
    let file_a = eng.core.sim_pfs_mut().create_file(size);
    let file_b = eng.core.sim_pfs_mut().create_file(size);
    // The cap and the single-shard pin are service scope (PR 5): set
    // once at boot, not smuggled through a file's open.
    let cfg = ServiceConfig {
        max_inflight_reads: Some(1),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let io = CkIo::boot_with(&mut eng, cfg).expect("valid config");
    let fopts = FileOptions::with_readers(2);
    let sopts = SessionOptions { splinter_bytes: Some(256 << 10), ..Default::default() };
    let fut = eng.future(2 * 2); // 2 sessions x 2 clients
    let leaders = [
        spawn_verified_session(
            &mut eng,
            io,
            file_a,
            size,
            2,
            fopts.clone(),
            sopts.clone(),
            true,
            Callback::Future(fut),
        ),
        spawn_verified_session(
            &mut eng,
            io,
            file_b,
            size,
            2,
            fopts,
            sopts,
            true,
            Callback::Future(fut),
        ),
    ];
    for l in leaders {
        eng.inject_signal(l, EP_GO);
    }
    eng.run();
    assert!(eng.future_done(fut), "not every client read completed");
    // Fully sequenced: the PFS never had two reads in flight.
    let peak = eng.core.metrics.value(ckio::metrics::keys::PFS_MAX_CONCURRENT);
    assert!(peak <= 1.0, "governor cap 1 violated: peak concurrent reads = {peak}");
    // Demand definitely exceeded the cap (2 sessions x 2 buffers x 8
    // splinters), so the governor must have deferred some of it.
    assert!(eng.core.metrics.counter("ckio.governor.throttled") > 0);
    // Both sessions' every byte was delivered exactly once, verified.
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 2 * size);
    assert_service_clean(&eng, &io);
    let director: &Director = eng.chare(io.director);
    assert_eq!(director.open_files(), 0);
    assert_eq!(director.active_shards(), 1, "the shard pin must have applied");
    assert_eq!(io.governor_inflight(&eng), 0, "tickets leaked in the governor");
    assert_eq!(io.governor_queued(&eng), 0, "demand stranded in the governor");
}

// ---------------------------------------------------------------------
// 5. Same-file concurrent sessions dedup their prefetch via the store
// ---------------------------------------------------------------------

/// Two concurrent sessions over one file: the second session's buffers
/// peer-fetch from the first's (waiting on its in-flight greedy reads),
/// so the PFS reads the file's bytes once — and contents still verify.
#[test]
fn concurrent_same_file_sessions_read_the_file_once() {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let size: u64 = 3 << 20;
    let file = eng.core.sim_pfs_mut().create_file(size);
    let io = CkIo::boot(&mut eng);
    let fopts = FileOptions::with_readers(4);
    let sopts = SessionOptions { splinter_bytes: Some(128 << 10), ..Default::default() };
    let fut = eng.future(2 * 3); // 2 sessions x 3 clients
    let leaders = [
        spawn_verified_session(
            &mut eng,
            io,
            file,
            size,
            3,
            fopts.clone(),
            sopts.clone(),
            true,
            Callback::Future(fut),
        ),
        spawn_verified_session(
            &mut eng,
            io,
            file,
            size,
            3,
            fopts,
            sopts,
            true,
            Callback::Future(fut),
        ),
    ];
    for l in leaders {
        eng.inject_signal(l, EP_GO);
    }
    eng.run();
    assert!(eng.future_done(fut));
    // The PFS was read once (both sessions' greedy prefetch overlapped
    // in time, so this is in-flight dedup, not parked reuse).
    assert_eq!(
        eng.core.metrics.counter("pfs.bytes_read"),
        size,
        "same-file concurrent sessions must not duplicate PFS traffic"
    );
    // The second session's bytes were store hits.
    assert_eq!(eng.core.metrics.counter("ckio.store.hit_bytes"), size);
    assert_eq!(eng.core.metrics.counter("ckio.store.miss_bytes"), size);
    // Both sessions delivered and verified everything.
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 2 * size);
    assert_service_clean(&eng, &io);
    let director: &Director = eng.chare(io.director);
    assert_eq!(director.open_files(), 0);
}

// ---------------------------------------------------------------------
// 6. Concurrent opens of one file are refcounted
// ---------------------------------------------------------------------

#[test]
fn concurrent_same_file_opens_share_one_open_and_refcount_closes() {
    let mut eng = Engine::new(EngineConfig::sim(1, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let size: u64 = 1 << 20;
    let file = eng.core.sim_pfs_mut().create_file(size);
    let io = CkIo::boot(&mut eng);
    // Two independent single-client sessions over the same file, started
    // simultaneously: their opens race, their closes race.
    let fut = eng.future(2);
    let l1 = spawn_verified_session(
        &mut eng,
        io,
        file,
        size,
        1,
        FileOptions::with_readers(2),
        SessionOptions::default(),
        true,
        Callback::Future(fut),
    );
    let l2 = spawn_verified_session(
        &mut eng,
        io,
        file,
        size,
        1,
        FileOptions::with_readers(2),
        SessionOptions::default(),
        true,
        Callback::Future(fut),
    );
    eng.inject_signal(l1, EP_GO);
    eng.inject_signal(l2, EP_GO);
    eng.run();
    assert!(eng.future_done(fut), "both sessions must complete");
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 2 * size);
    // One of the two opens was answered from the shared open/file table.
    assert_eq!(eng.core.metrics.counter("ckio.reopens"), 1);
    let director: &Director = eng.chare(io.director);
    assert_eq!(director.open_files(), 0, "both closes must finally release the file");
    assert_service_clean(&eng, &io);
}
