//! Data-plane shard integration tests (PR 3): FileId→shard routing
//! stability, per-shard admission semantics, and teardown with tickets
//! in flight on a shard whose file is closing.
//!
//! * **Routing stability** — a file's data-plane state (claims, parked
//!   arrays, governor tickets) lives on exactly one shard, the same one
//!   across close/re-open, and never leaks onto other shards.
//! * **Per-shard caps** — `max_inflight_reads` is enforced per shard:
//!   two files on different shards proceed concurrently under cap = 1
//!   (the PFS observes 2 reads in flight), while two sessions of *one*
//!   file — same shard by the routing invariant — are still fully
//!   sequenced.
//! * **Teardown drain** — closing a governed session (and then its file)
//!   with admission tickets in flight leaves no ticket leaked and no
//!   demand stranded on the shard, and every read callback still fires
//!   exactly once.

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::topology::Pe;
use ckio::ckio::director::Director;
use ckio::ckio::{
    CkIo, FileOptions, ReadResult, ServiceConfig, Session, SessionId, SessionOptions,
};
use ckio::harness::experiments::assert_service_clean;
use ckio::impl_chare_any;
use ckio::metrics::keys;
use ckio::pfs::{FileId, PfsConfig};

const MIB: u64 = 1 << 20;

fn verified_engine(
    nfiles: u32,
    file_size: u64,
    cfg: ServiceConfig,
) -> (Engine, Vec<FileId>, CkIo) {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let files = (0..nfiles).map(|_| eng.core.sim_pfs_mut().create_file(file_size)).collect();
    let io = CkIo::boot_with(&mut eng, cfg).expect("valid ServiceConfig");
    (eng, files, io)
}

fn open_file(eng: &mut Engine, io: &CkIo, file: FileId, size: u64, opts: FileOptions) {
    let fut = eng.future(1);
    io.open_driver(eng, file, size, opts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "open never completed");
}

fn start_session(
    eng: &mut Engine,
    io: &CkIo,
    file: FileId,
    offset: u64,
    bytes: u64,
    sopts: SessionOptions,
) -> Session {
    let fut = eng.future(1);
    io.start_session_driver(eng, file, offset, bytes, sopts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session never became ready");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    p.take::<Session>()
}

fn close_session(eng: &mut Engine, io: &CkIo, sid: SessionId) {
    let fut = eng.future(1);
    io.close_session_driver(eng, sid, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session close never completed");
}

fn close_file(eng: &mut Engine, io: &CkIo, file: FileId) {
    let fut = eng.future(1);
    io.close_file_driver(eng, file, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "file close never completed");
}

/// Claims for `file` on every shard: the routing invariant says exactly
/// one shard may ever report a nonzero count.
fn claims_per_shard(eng: &Engine, io: &CkIo, file: FileId) -> Vec<usize> {
    (0..io.nshards).map(|s| io.shard(eng, s).span_store().claims_for(file)).collect()
}

// ---------------------------------------------------------------------
// 1. FileId→shard routing is stable across re-open and never leaks
// ---------------------------------------------------------------------

#[test]
fn file_to_shard_routing_is_stable_across_reopen() {
    let size = MIB;
    let (mut eng, files, io) = verified_engine(2, size, ServiceConfig::default());
    let opts = FileOptions::with_readers(2);
    open_file(&mut eng, &io, files[0], size, opts.clone());
    open_file(&mut eng, &io, files[1], size, opts.clone());

    let home = eng.chare::<Director>(io.director).shard_of_file(files[0]);
    let other = eng.chare::<Director>(io.director).shard_of_file(files[1]);
    assert_ne!(home, other, "dense FileIds must spread over the default shard count");

    // A live session's claims land on the home shard — and only there.
    let s = start_session(&mut eng, &io, files[0], 0, size, SessionOptions::default());
    let claims = claims_per_shard(&eng, &io, files[0]);
    assert_eq!(claims[home as usize], 2, "one claim per (nonempty) buffer span");
    for (i, &c) in claims.iter().enumerate() {
        if i != home as usize {
            assert_eq!(c, 0, "file 0 claims leaked onto shard {i}");
        }
    }

    // Dropping the session retracts the claims (buffer-side unclaim).
    close_session(&mut eng, &io, s.id);
    assert!(claims_per_shard(&eng, &io, files[0]).iter().all(|&c| c == 0));

    // Full close + re-open: same shard (the active shard count is
    // fixed at boot since PR 5, so routing can never move).
    close_file(&mut eng, &io, files[0]);
    open_file(&mut eng, &io, files[0], size, opts);
    assert_eq!(
        eng.chare::<Director>(io.director).shard_of_file(files[0]),
        home,
        "re-opening a file must not move its data-plane state"
    );
    let s2 = start_session(&mut eng, &io, files[0], 0, size, SessionOptions::default());
    assert_eq!(claims_per_shard(&eng, &io, files[0])[home as usize], 2);
    close_session(&mut eng, &io, s2.id);
    close_file(&mut eng, &io, files[0]);
    close_file(&mut eng, &io, files[1]);
    assert_service_clean(&eng, &io);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}

/// The PR 4 residency summary answers "who holds these bytes" on the
/// home shard and only there — the one-probe promise store-aware
/// placement is built on. The store-level plan (dominant source per
/// prospective span) agrees with where the live session's buffers
/// actually sit.
#[test]
fn residency_summary_and_plan_live_on_the_home_shard_only() {
    let size = MIB;
    let (mut eng, files, io) = verified_engine(1, size, ServiceConfig::default());
    let file = files[0];
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));
    let s = start_session(&mut eng, &io, file, 0, size, SessionOptions::default());

    let home = eng.chare::<Director>(io.director).shard_of_file(file);
    for i in 0..io.nshards {
        let by_pe = io.shard(&eng, i).span_store().residency_by_pe(file);
        if i == home {
            // Two live claims of half the file each, on the two PEs the
            // session's buffers were placed on.
            assert_eq!(by_pe.iter().map(|&(_, b)| b).sum::<u64>(), size);
            assert_eq!(by_pe.len(), 2, "one residency entry per buffer PE");
            for (pe, _) in &by_pe {
                let owned = (0..2).any(|b| eng.pe_of(ChareRef::new(s.buffers, b)).0 == *pe);
                assert!(owned, "residency reported on PE {pe} where no buffer sits");
            }
        } else {
            assert!(by_pe.is_empty(), "residency leaked onto shard {i}");
        }
    }
    // A prospective 4-reader plan over the same range: every span is
    // covered (each quarter sits inside one half-file claim), and each
    // dominant source is a PE that really holds the bytes.
    let plan = io.shard(&eng, home).span_store().plan_spans(file, 0, size, 4, 0);
    assert_eq!(plan.len(), 4);
    for (b, src) in plan.into_iter().enumerate() {
        let src = src.expect("every quarter span has a resident source");
        assert_eq!(src.covered, size / 4, "span {b} must be fully covered");
        let source_buffer = (b / 2) as u32; // quarters 0,1 → buffer 0; 2,3 → buffer 1
        assert_eq!(src.pe, eng.pe_of(ChareRef::new(s.buffers, source_buffer)).0);
    }

    close_session(&mut eng, &io, s.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 2. Per-shard admission: distinct files proceed, same file sequences
// ---------------------------------------------------------------------

/// Read `[offset, offset+len)` through PE 0's manager (the public
/// `read_driver`, PR 5) and verify every byte against the deterministic
/// file pattern.
fn read_verified(eng: &mut Engine, io: &CkIo, s: &Session, file: FileId, offset: u64, len: u64) {
    let fut = eng.future(1);
    io.read_driver(eng, 0, s, offset, len, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "read callback never fired");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    let r = p.take::<ReadResult>();
    assert_eq!(r.len, len);
    let bytes = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
    assert_eq!(ckio::pfs::pattern::verify(file, offset, bytes), None, "corrupt read");
}

#[test]
fn distinct_files_on_distinct_shards_admit_independently_under_cap_one() {
    let size = MIB;
    // Per-shard cap of 1 is service scope (PR 5): configured at boot,
    // enforced by every active shard.
    let cfg = ServiceConfig { max_inflight_reads: Some(1), ..Default::default() };
    let (mut eng, files, io) = verified_engine(2, size, cfg);
    let fopts = FileOptions::with_readers(2);
    let sopts = SessionOptions { splinter_bytes: Some(128 << 10), ..Default::default() };
    // Open both files and start both sessions in one scheduling window,
    // so the two greedy prefetches run concurrently.
    io.open_driver(&mut eng, files[0], size, fopts.clone(), Callback::Ignore);
    io.open_driver(&mut eng, files[1], size, fopts, Callback::Ignore);
    let ready = eng.future(2);
    io.start_session_driver(&mut eng, files[0], 0, size, sopts.clone(), Callback::Future(ready));
    io.start_session_driver(&mut eng, files[1], 0, size, sopts, Callback::Future(ready));
    eng.run();
    assert!(eng.future_done(ready), "sessions never became ready");

    // Different shards govern independently: the PFS saw exactly two
    // concurrent reads — more than a global cap of 1 would ever allow
    // (the sessions were NOT serialized), and never more than one per
    // shard (the per-shard caps held).
    let peak = eng.core.metrics.value(keys::PFS_MAX_CONCURRENT);
    assert_eq!(
        peak, 2.0,
        "per-shard cap 1 over two files on two shards must admit exactly 2 concurrent reads"
    );
    let sessions: Vec<Session> = eng
        .take_future(ready)
        .into_iter()
        .map(|(_, mut p)| p.take::<Session>())
        .collect();
    for s in &sessions {
        read_verified(&mut eng, &io, s, s.file, 0, size);
    }
    // Both shards actually carried data-plane traffic.
    let d0 = eng.chare::<Director>(io.director).shard_of_file(files[0]);
    let d1 = eng.chare::<Director>(io.director).shard_of_file(files[1]);
    assert!(io.shard(&eng, d0).msgs_processed() > 0);
    assert!(io.shard(&eng, d1).msgs_processed() > 0);
    for s in sessions {
        close_session(&mut eng, &io, s.id);
    }
    close_file(&mut eng, &io, files[0]);
    close_file(&mut eng, &io, files[1]);
    assert_service_clean(&eng, &io);
}

#[test]
fn same_file_sessions_still_fully_sequence_under_per_shard_cap_one() {
    let size = 2 * MIB;
    let cfg = ServiceConfig { max_inflight_reads: Some(1), ..Default::default() };
    let (mut eng, files, io) = verified_engine(1, size, cfg);
    let file = files[0];
    let sopts = SessionOptions { splinter_bytes: Some(128 << 10), ..Default::default() };
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Ignore);
    // Two concurrent sessions over non-overlapping halves of ONE file:
    // same file → same shard → one cap. (Disjoint ranges, so the span
    // store cannot dedup any read away — every byte takes a ticket.)
    let ready = eng.future(2);
    io.start_session_driver(&mut eng, file, 0, size / 2, sopts.clone(), Callback::Future(ready));
    io.start_session_driver(
        &mut eng,
        file,
        size / 2,
        size / 2,
        sopts,
        Callback::Future(ready),
    );
    eng.run();
    assert!(eng.future_done(ready));
    let peak = eng.core.metrics.value(keys::PFS_MAX_CONCURRENT);
    assert!(
        peak <= 1.0,
        "same-file sessions share one shard and must stay fully sequenced, saw {peak}"
    );
    assert!(eng.core.metrics.counter(keys::GOV_THROTTLED) > 0, "cap 1 must defer demand");
    let sessions: Vec<Session> = eng
        .take_future(ready)
        .into_iter()
        .map(|(_, mut p)| p.take::<Session>())
        .collect();
    for s in sessions {
        close_session(&mut eng, &io, s.id);
    }
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 3. Teardown with tickets in flight on a shard whose file is closing
// ---------------------------------------------------------------------

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;
const EP_CLOSED: Ep = 5;
const EP_FCLOSED: Ep = 6;

/// Opens a governed file, starts a session, then issues `n_reads` reads
/// and the session close *in the same handler* — so the drop races
/// fetches, in-flight greedy reads, and governor tickets — and finally
/// closes the file (purging the shard) while late grants and ticket
/// returns are still landing.
struct GovernedRacyCloser {
    io: CkIo,
    file: FileId,
    size: u64,
    n_reads: u32,
    reads_seen: u32,
    closed: bool,
    file_closed: bool,
    done: Callback,
}

impl GovernedRacyCloser {
    fn maybe_done(&mut self, ctx: &mut Ctx<'_>) {
        if self.file_closed && self.reads_seen == self.n_reads {
            let done = self.done.clone();
            ctx.fire(done, Payload::empty());
        }
    }
}

impl Chare for GovernedRacyCloser {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.size);
                io.open(
                    ctx,
                    file,
                    size,
                    FileOptions::with_readers(4),
                    Callback::to_chare(me, EP_OPENED),
                );
            }
            EP_OPENED => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.size);
                io.start_read_session(
                    ctx,
                    file,
                    0,
                    size,
                    SessionOptions { splinter_bytes: Some(64 << 10), ..Default::default() },
                    Callback::to_chare(me, EP_READY),
                );
            }
            EP_READY => {
                let s: Session = msg.take();
                let me = ctx.me();
                let io = self.io;
                // Reads and the close depart together: with cap 1 and 64
                // KiB splinters, nearly all greedy demand is still queued
                // at (or in flight through) the shard's governor when the
                // drop lands.
                let per = self.size / self.n_reads as u64;
                for i in 0..self.n_reads as u64 {
                    io.read(ctx, &s, i * per, per, Callback::to_chare(me, EP_DATA));
                }
                io.close_read_session(ctx, s.id, Callback::to_chare(me, EP_CLOSED));
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                assert!(r.len > 0);
                self.reads_seen += 1;
                assert!(self.reads_seen <= self.n_reads, "a read callback fired twice");
                self.maybe_done(ctx);
            }
            EP_CLOSED => {
                assert!(!self.closed, "close callback fired twice");
                self.closed = true;
                // Close the file immediately: the shard purge races the
                // buffers' unclaims and the governor's grant/return
                // cycle for the tickets still parked there.
                let me = ctx.me();
                let (io, file) = (self.io, self.file);
                io.close(ctx, file, Callback::to_chare(me, EP_FCLOSED));
            }
            EP_FCLOSED => {
                self.file_closed = true;
                self.maybe_done(ctx);
            }
            other => panic!("GovernedRacyCloser: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

#[test]
fn teardown_drains_inflight_tickets_on_a_closing_shard() {
    // The governed cap the teardown races against is boot configuration.
    let cfg = ServiceConfig { max_inflight_reads: Some(1), ..Default::default() };
    let (mut eng, files, io) = verified_engine(1, MIB, cfg);
    let fut = eng.future(1);
    let c = eng.create_singleton(Pe(1), GovernedRacyCloser {
        io,
        file: files[0],
        size: MIB,
        n_reads: 8,
        reads_seen: 0,
        closed: false,
        file_closed: false,
        done: Callback::Future(fut),
    });
    eng.inject_signal(c, EP_GO);
    eng.run(); // must quiesce: every ticket returned, every grant resolved
    assert!(eng.future_done(fut), "reads or closes never completed");
    let closer: &GovernedRacyCloser = eng.chare(c);
    assert_eq!(closer.reads_seen, 8, "every outstanding read completes exactly once");
    assert!(closer.closed && closer.file_closed);
    // The shard holds no residue: no leaked tickets, no stranded
    // demand, no claims or parked arrays for the purged file.
    assert_service_clean(&eng, &io);
    assert!(claims_per_shard(&eng, &io, files[0]).iter().all(|&c| c == 0));
    assert_eq!(io.cached_buffer_arrays(&eng), 0);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
}
