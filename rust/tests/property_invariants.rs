//! Property-based tests over the coordinator's core invariants
//! (routing, batching/assembly, session state), using the seeded
//! mini-prop framework in `ckio::util::prop`.

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef, CollectionId};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::topology::{Pe, Placement};
use ckio::ckio::{
    CkIo, FileOptions, ReadResult, Session, SessionId, SessionOptions,
};
use ckio::impl_chare_any;
use ckio::pfs::{pattern, FileId, PfsConfig};
use ckio::prop_assert;
use ckio::util::prop::{forall, PropConfig};

// ---------------------------------------------------------------------
// Pure invariants
// ---------------------------------------------------------------------

#[test]
fn prop_session_spans_partition_exactly() {
    forall(PropConfig { cases: 400, ..Default::default() }, "session_spans", |g| {
        let offset = g.range(0, 1 << 30);
        let bytes = 1 + g.sized();
        let nbuf = g.range(1, 128) as u32;
        let s = Session::new(SessionId(0), FileId(0), offset, bytes, CollectionId(0), nbuf);
        let mut pos = offset;
        for b in 0..nbuf {
            let (o, l) = s.buffer_span(b);
            prop_assert!(o == pos, "gap at buffer {b}: {o} != {pos}");
            pos = o + l;
        }
        prop_assert!(pos == offset + bytes, "spans cover {pos}, want {}", offset + bytes);
        // buffer_of agrees with buffer_span for random probes.
        for _ in 0..8 {
            let probe = g.range(offset, offset + bytes);
            let b = s.buffer_of(probe);
            let (o, l) = s.buffer_span(b);
            prop_assert!(
                probe >= o && probe < o + l,
                "buffer_of({probe})={b} span [{o},{})",
                o + l
            );
        }
        Ok(())
    });
}

#[test]
fn prop_rpc_extents_partition_and_stay_on_one_ost() {
    use ckio::pfs::FileMeta;
    forall(PropConfig { cases: 300, ..Default::default() }, "rpc_extents", |g| {
        let stripe = 1 << g.range(12, 24); // 4 KiB .. 16 MiB
        let size = stripe * g.range(1, 64) + g.range(1, stripe);
        let meta = FileMeta {
            id: FileId(0),
            size,
            stripe_size: stripe,
            stripe_count: g.range(1, 16) as u32,
            first_ost: g.range(0, 16) as u32,
            path: None,
        };
        let offset = g.range(0, size);
        let len = 1 + g.range(0, size - offset);
        let rpc_max = 1 << g.range(12, 23);
        let exts = meta.rpc_extents(offset, len, rpc_max);
        let mut pos = offset;
        for &(o, l) in &exts {
            prop_assert!(o == pos, "extent gap: {o} != {pos}");
            prop_assert!(l > 0 && l <= rpc_max, "bad extent len {l}");
            prop_assert!(
                meta.ost_of(o, 16) == meta.ost_of(o + l - 1, 16),
                "extent [{o},{}) spans OSTs",
                o + l
            );
            pos = o + l;
        }
        prop_assert!(pos == offset + len, "extents cover {pos}, want {}", offset + len);
        Ok(())
    });
}

#[test]
fn prop_pattern_slices_are_consistent() {
    forall(PropConfig { cases: 200, max_size: 1 << 16, ..Default::default() }, "pattern", |g| {
        let file = FileId(g.range(0, 8) as u32);
        let off = g.range(0, 1 << 20);
        let len = 1 + g.range(0, 4096);
        let whole = pattern::make(file, off, len + 64);
        let part = pattern::make(file, off + 13, (len + 13).min(len + 64) - 13);
        prop_assert!(
            whole[13..13 + part.len()] == part[..],
            "slice mismatch at off={off} len={len}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// End-to-end engine properties
// ---------------------------------------------------------------------

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;
const EP_FWD: Ep = 5;

/// A client that reads an arbitrary list of (offset, len) extents,
/// optionally migrating between reads, verifying every byte.
struct FuzzClient {
    io: CkIo,
    file: FileId,
    file_size: u64,
    index: u32,
    peers: CollectionId,
    n_peers: u32,
    extents: Vec<(u64, u64)>,
    next: usize,
    migrate_every: Option<u32>,
    reads_done: u32,
    session: Option<Session>,
    done: Callback,
    fopts: FileOptions,
    sopts: SessionOptions,
}

impl FuzzClient {
    fn issue_or_finish(&mut self, ctx: &mut Ctx<'_>) {
        // Skip empty extents.
        while self.next < self.extents.len() && self.extents[self.next].1 == 0 {
            self.next += 1;
        }
        if self.next >= self.extents.len() {
            let done = self.done.clone();
            ctx.fire(done, Payload::new(self.reads_done));
            return;
        }
        let (o, l) = self.extents[self.next];
        self.next += 1;
        let s = *self.session.as_ref().unwrap();
        let me = ctx.me();
        let io = self.io;
        io.read(ctx, &s, o, l, Callback::to_chare(me, EP_DATA));
    }
}

impl Chare for FuzzClient {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                let me = ctx.me();
                let (io, file, size, fopts) =
                    (self.io, self.file, self.file_size, self.fopts.clone());
                io.open(ctx, file, size, fopts, Callback::to_chare(me, EP_OPENED));
            }
            EP_OPENED => {
                let me = ctx.me();
                let (io, file, size, sopts) =
                    (self.io, self.file, self.file_size, self.sopts.clone());
                io.start_read_session(
                    ctx,
                    file,
                    0,
                    size,
                    sopts,
                    Callback::to_chare(me, EP_READY),
                );
            }
            EP_READY | EP_FWD => {
                let s: Session = msg.take();
                if msg.ep == EP_READY {
                    for j in 0..self.n_peers {
                        if j != self.index {
                            ctx.send(ChareRef::new(self.peers, j), EP_FWD, s);
                        }
                    }
                }
                self.session = Some(s);
                self.issue_or_finish(ctx);
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                let bytes = r.chunk.bytes.as_ref().expect("materialized");
                assert_eq!(bytes.len() as u64, r.len);
                assert_eq!(
                    pattern::verify(self.file, r.offset, bytes),
                    None,
                    "corrupt read at {} len {}",
                    r.offset,
                    r.len
                );
                self.reads_done += 1;
                if let Some(k) = self.migrate_every {
                    if self.reads_done % k == 0 {
                        let npes = ctx.topo().npes();
                        let dest = Pe((ctx.pe().0 + 1 + self.reads_done % 3) % npes);
                        ctx.migrate_me(dest);
                    }
                }
                self.issue_or_finish(ctx);
            }
            other => panic!("FuzzClient: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// THE core property: for random cluster shapes, file sizes, reader
/// counts, splinter settings, random per-client extent lists (a random
/// partition of the file so global coverage is exact), and random
/// migration cadences — every byte is delivered exactly once, with
/// correct contents, and the run quiesces.
#[test]
fn prop_ckio_delivers_every_byte_exactly_once() {
    let cfg = PropConfig { cases: 40, max_size: 4 << 20, seed: 0xF00D, ..Default::default() };
    forall(cfg, "ckio_e2e", |g| {
        let nodes = g.range(1, 4) as u32;
        let pes = g.range(1, 4) as u32;
        let file_size = 4096 + g.sized(); // up to ~4 MiB
        let nclients = g.range(1, 16) as u32;
        let readers = g.range(1, 8) as u32;
        let splinter = if g.chance(0.4) { Some(1 + g.range(0, file_size)) } else { None };
        let migrate = if g.chance(0.4) { Some(1 + g.range(0, 3) as u32) } else { None };

        let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(g.range(0, 1 << 20)))
            .with_sim_pfs(PfsConfig {
                materialize: true,
                noise_sigma: 0.02,
                ..PfsConfig::default()
            });
        let file = eng.core.sim_pfs_mut().create_file(file_size);
        let io = CkIo::boot(&mut eng);
        let fut = eng.future(nclients);

        // Random partition of the file across clients; each client then
        // splits its slice into 1..4 random sub-reads.
        let slices = g.partition(file_size, nclients as usize);
        let mut extents_per_client: Vec<Vec<(u64, u64)>> = Vec::new();
        for &(o, l) in &slices {
            if l == 0 {
                extents_per_client.push(vec![]);
                continue;
            }
            let pieces = g.range(1, 4) as usize;
            let sub = g.partition(l, pieces);
            extents_per_client.push(sub.into_iter().map(|(so, sl)| (o + so, sl)).collect());
        }

        let fopts = FileOptions::with_readers(readers);
        let sopts = SessionOptions { splinter_bytes: splinter, ..Default::default() };
        let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| FuzzClient {
            io,
            file,
            file_size,
            index: i,
            peers: CollectionId(u32::MAX),
            n_peers: nclients,
            extents: extents_per_client[i as usize].clone(),
            next: 0,
            migrate_every: migrate,
            reads_done: 0,
            session: None,
            done: Callback::Future(fut),
            fopts: fopts.clone(),
            sopts: sopts.clone(),
        });
        for i in 0..nclients {
            eng.chare_mut::<FuzzClient>(ChareRef::new(cid, i)).peers = cid;
        }
        eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
        eng.run();
        prop_assert!(eng.future_done(fut), "run did not complete (deadlock?)");
        let delivered = eng.core.metrics.counter("ckio.bytes_delivered");
        prop_assert!(
            delivered == file_size,
            "delivered {delivered} of {file_size} bytes (readers={readers} splinter={splinter:?} migrate={migrate:?})"
        );
        Ok(())
    });
}

/// Location management under randomized migration storms: messages for
/// a chare that keeps moving are always delivered, exactly once each.
#[test]
fn prop_messages_chase_migrating_chares() {
    struct Hopper {
        seen: u32,
        hops: Vec<Pe>,
        next_hop: usize,
        done: Callback,
        expect: u32,
    }
    const EP_POKE: Ep = 1;
    impl Chare for Hopper {
        fn receive(&mut self, ctx: &mut Ctx<'_>, msg: Msg) {
            assert_eq!(msg.ep, EP_POKE);
            self.seen += 1;
            if self.next_hop < self.hops.len() {
                let d = self.hops[self.next_hop];
                self.next_hop += 1;
                if d != ctx.pe() {
                    ctx.migrate_me(d);
                }
            }
            if self.seen == self.expect {
                let done = self.done.clone();
                ctx.fire(done, Payload::new(self.seen));
            }
        }
        impl_chare_any!();
    }

    forall(PropConfig { cases: 60, ..Default::default() }, "migration_storm", |g| {
        let nodes = g.range(1, 4) as u32;
        let pes = g.range(1, 4) as u32;
        let npes = nodes * pes;
        let n_msgs = g.range(1, 40) as u32;
        let hops: Vec<Pe> =
            (0..g.range(0, 20)).map(|_| Pe(g.range(0, npes as u64) as u32)).collect();

        let mut eng = Engine::new(EngineConfig::sim(nodes, pes).with_seed(g.range(0, 1 << 20)));
        let fut = eng.future(1);
        let cid = eng.create_array(1, &Placement::RoundRobinPes, |_| Hopper {
            seen: 0,
            hops: hops.clone(),
            next_hop: 0,
            done: Callback::Future(fut),
            expect: n_msgs,
        });
        let target = ChareRef::new(cid, 0);
        for _ in 0..n_msgs {
            eng.inject_signal(target, EP_POKE);
        }
        eng.run();
        prop_assert!(eng.future_done(fut), "messages lost under migration");
        let seen = eng.chare::<Hopper>(target).seen;
        prop_assert!(seen == n_msgs, "delivered {seen} of {n_msgs}");
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Failure / race injection
// ---------------------------------------------------------------------

/// Closing a session while buffer prefetch reads are still in flight
/// must not crash or leak: late completions are dropped.
#[test]
fn close_session_races_inflight_prefetch() {
    struct Closer {
        io: CkIo,
        file: FileId,
        size: u64,
        done: Callback,
    }
    const EP_CLOSED: Ep = 7;
    impl Chare for Closer {
        fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
            match msg.ep {
                EP_GO => {
                    let me = ctx.me();
                    let (io, file, size) = (self.io, self.file, self.size);
                    io.open(
                        ctx,
                        file,
                        size,
                        FileOptions::with_readers(4),
                        Callback::to_chare(me, EP_OPENED),
                    );
                }
                EP_OPENED => {
                    let me = ctx.me();
                    let (io, file, size) = (self.io, self.file, self.size);
                    io.start_read_session(
                        ctx,
                        file,
                        0,
                        size,
                        SessionOptions::default(),
                        Callback::to_chare(me, EP_READY),
                    );
                }
                EP_READY => {
                    // Close immediately: the buffers' greedy reads (256 MiB
                    // span each) are certainly still in the PFS queues.
                    let s: Session = msg.take();
                    let me = ctx.me();
                    let io = self.io;
                    io.close_read_session(ctx, s.id, Callback::to_chare(me, EP_CLOSED));
                }
                EP_CLOSED => {
                    let done = self.done.clone();
                    ctx.fire(done, Payload::empty());
                }
                other => panic!("unknown ep {other}"),
            }
        }
        impl_chare_any!();
    }

    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(1 << 30);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(1);
    let c = eng
        .create_singleton(Pe(1), Closer { io, file, size: 1 << 30, done: Callback::Future(fut) });
    eng.inject_signal(c, EP_GO);
    eng.run(); // must quiesce without panicking on late completions
    assert!(eng.future_done(fut));
}

/// Reads that race ahead of the session announcement on a PE are held by
/// the manager and served once the announcement lands.
#[test]
fn early_reads_are_buffered_by_manager() {
    use ckio::ckio::manager::{Manager, ReadMsg, EP_M_READ};

    let mut eng = Engine::new(EngineConfig::sim(1, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        ..PfsConfig::default()
    });
    let file = eng.core.sim_pfs_mut().create_file(1 << 20);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(1);

    // Inject a read for a session id that will be announced by a
    // concurrent open+start driven from the driver.
    io.open_driver(&mut eng, file, 1 << 20, FileOptions::with_readers(2), Callback::Ignore);
    // The director assigns session ids sequentially from 0.
    eng.inject(
        ChareRef::new(io.managers, 0),
        EP_M_READ,
        ReadMsg { session: SessionId(0), offset: 0, len: 4096, after: Callback::Future(fut) },
    );
    // Start the session (driver-side) after the early read is in flight.
    io.start_session_driver(
        &mut eng,
        file,
        0,
        1 << 20,
        SessionOptions::default(),
        Callback::Ignore,
    );
    eng.run();
    assert!(eng.future_done(fut), "early read was never served");
    // Manager state is clean (no stuck early queue).
    let mgr: &Manager = eng.chare(ChareRef::new(io.managers, 0));
    assert!(mgr.knows_session(SessionId(0)));
}

/// Zero-length client slices and 1-byte files: degenerate shapes hold.
#[test]
fn degenerate_shapes() {
    // 1-byte file, 1 client, 1 reader.
    let (t, eng) = ckio::harness::experiments::run_ckio_read(
        1,
        1,
        1,
        1,
        FileOptions::with_readers(1),
        SessionOptions::default(),
        3,
    );
    assert!(t > 0);
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 1);
    // More readers than bytes: clamped, still correct.
    let (_, eng) = ckio::harness::experiments::run_ckio_read(
        1,
        2,
        7,
        3,
        FileOptions::with_readers(64),
        SessionOptions::default(),
        4,
    );
    assert_eq!(eng.core.metrics.counter("ckio.bytes_delivered"), 7);
}
