//! Integration tests: the full CkIO protocol over the AMT runtime and the
//! simulated PFS, with end-to-end data verification, overlap behaviour,
//! splintered I/O, migration, and the real-disk wall-clock path.

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::time::{Time, MILLIS};
use ckio::amt::topology::{Pe, Placement};
use ckio::ckio::{CkIo, FileOptions, ReadResult, Session, SessionOptions};
use ckio::impl_chare_any;
use ckio::pfs::{pattern, FileId, PfsConfig};

// ---------------------------------------------------------------------
// A test client chare: opens, starts a session, reads its slice (possibly
// in several pieces), verifies the bytes, reports completion.
// ---------------------------------------------------------------------

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;

struct Client {
    io: CkIo,
    file: FileId,
    file_size: u64,
    /// My slice of the session.
    my_offset: u64,
    my_len: u64,
    /// Read granularity (0 = single read).
    piece: u64,
    /// Set on the one client that drives open+session for everyone.
    leader_for: Option<u32>, // number of clients
    session: Option<Session>,
    received: u64,
    verify: bool,
    done: Callback,
    migrate_between_reads: Option<Pe>,
}

impl Client {
    fn issue_reads(&mut self, ctx: &mut Ctx<'_>) {
        let s = self.session.as_ref().unwrap();
        let me = ctx.me();
        let step = if self.piece == 0 { self.my_len } else { self.piece };
        let mut o = self.my_offset;
        while o < self.my_offset + self.my_len {
            let l = step.min(self.my_offset + self.my_len - o);
            self.io.read(ctx, s, o, l, Callback::to_chare(me, EP_DATA));
            o += l;
        }
    }
}

impl Chare for Client {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_GO => {
                // Only the leader opens the file + starts the session.
                if self.leader_for.is_some() {
                    let me = ctx.me();
                    self.io.open(
                        ctx,
                        self.file,
                        self.file_size,
                        FileOptions::with_readers(4),
                        Callback::to_chare(me, EP_OPENED),
                    );
                }
            }
            EP_OPENED => {
                let me = ctx.me();
                self.io
                    .start_read_session(
                        ctx,
                        self.file,
                        0,
                        self.file_size,
                        SessionOptions::default(),
                        Callback::to_chare(me, EP_READY),
                    );
            }
            EP_READY => {
                let s: Session = msg.take();
                // Leader forwards the session handle to every client.
                let n = self.leader_for.unwrap();
                for i in 0..n {
                    ctx.send(ChareRef::new(ctx.me().collection, i), EP_READY_FWD, s);
                }
            }
            EP_READY_FWD => {
                let s: Session = msg.take();
                self.session = Some(s);
                self.issue_reads(ctx);
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                if self.verify {
                    let bytes = r.chunk.bytes.as_ref().expect("materialized run");
                    assert_eq!(bytes.len() as u64, r.len);
                    assert_eq!(
                        pattern::verify(self.file, r.offset, bytes),
                        None,
                        "data corruption at offset {}",
                        r.offset
                    );
                }
                self.received += r.len;
                assert!(self.received <= self.my_len, "over-delivery");
                if let Some(dest) = self.migrate_between_reads.take() {
                    ctx.migrate_me(dest);
                }
                if self.received == self.my_len {
                    ctx.fire(self.done.clone(), Payload::new(self.received));
                }
            }
            other => panic!("Client: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

const EP_READY_FWD: Ep = 9;

#[allow(clippy::too_many_arguments)]
fn run_clients(
    nodes: u32,
    pes: u32,
    nclients: u32,
    file_size: u64,
    piece: u64,
    verify: bool,
    migrate: bool,
) -> (Time, Engine) {
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes)).with_sim_pfs(PfsConfig {
        materialize: verify,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(nclients);
    let per = file_size / nclients as u64;
    assert_eq!(per * nclients as u64, file_size, "test wants an even split");
    let npes = nodes * pes;
    let cid = eng.create_array(nclients, &Placement::RoundRobinPes, |i| Client {
        io,
        file,
        file_size,
        my_offset: i as u64 * per,
        my_len: per,
        piece,
        leader_for: if i == 0 { Some(nclients) } else { None },
        session: None,
        received: 0,
        verify,
        done: Callback::Future(fut),
        migrate_between_reads: if migrate {
            Some(Pe((i + npes / 2) % npes))
        } else {
            None
        },
    });
    eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
    let end = eng.run();
    assert!(eng.future_done(fut), "not all clients finished");
    let total: u64 = eng
        .take_future(fut)
        .into_iter()
        .map(|(_, mut p)| p.take::<u64>())
        .sum();
    assert_eq!(total, file_size, "every byte delivered exactly once");
    (end, eng)
}

#[test]
fn full_protocol_delivers_verified_data() {
    let (end, eng) = run_clients(2, 2, 8, 4 << 20, 0, true, false);
    assert!(end > 0);
    let m = &eng.core.metrics;
    assert_eq!(m.counter("ckio.reads_served"), 8);
    assert_eq!(m.counter("ckio.bytes_delivered"), 4 << 20);
    assert!(m.counter("ckio.sessions") == 1);
}

#[test]
fn many_overdecomposed_clients() {
    // 64 clients on 4 PEs (16× over-decomposition), multi-piece reads.
    let (_, eng) = run_clients(2, 2, 64, 8 << 20, 32 << 10, true, false);
    let m = &eng.core.metrics;
    assert_eq!(m.counter("ckio.bytes_delivered"), 8 << 20);
    // 8 MiB / 32 KiB = 256 reads.
    assert_eq!(m.counter("ckio.reads_served"), 256);
}

#[test]
fn reads_spanning_buffer_boundaries() {
    // 3 clients over 4 buffers: client slices don't align with buffer
    // spans, so some reads need pieces from 2 buffers.
    let mut eng = Engine::new(EngineConfig::sim(1, 3)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let size: u64 = 3 << 20;
    let file = eng.core.sim_pfs_mut().create_file(size);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(3);
    let per = size / 3;
    let cid = eng.create_array(3, &Placement::RoundRobinPes, |i| Client {
        io,
        file,
        file_size: size,
        my_offset: i as u64 * per,
        my_len: per,
        piece: 0,
        leader_for: if i == 0 { Some(3) } else { None },
        session: None,
        received: 0,
        verify: true,
        done: Callback::Future(fut),
        migrate_between_reads: None,
    });
    eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
    eng.run();
    assert!(eng.future_done(fut));
}

#[test]
fn clients_migrate_between_reads() {
    // Every client migrates to a different PE mid-stream; reads keep
    // arriving correctly (location-managed callbacks).
    let (_, eng) = run_clients(2, 2, 8, 4 << 20, 128 << 10, true, true);
    let m = &eng.core.metrics;
    assert_eq!(m.counter("ckio.bytes_delivered"), 4 << 20);
    assert!(m.counter("amt.migrations") >= 8, "migrations happened");
}

#[test]
fn splintered_session_serves_early() {
    // With splintering, a read of the first bytes completes well before
    // the whole buffer span has been read.
    let run = |splinter: Option<u64>| -> Time {
        let mut eng = Engine::new(EngineConfig::sim(1, 2)).with_sim_pfs(PfsConfig {
            noise_sigma: 0.0,
            ..PfsConfig::default()
        });
        let size: u64 = 256 << 20;
        let file = eng.core.sim_pfs_mut().create_file(size);
        let io = CkIo::boot(&mut eng);

        struct FirstByte {
            io: CkIo,
            file: FileId,
            size: u64,
            splinter: Option<u64>,
            done: Callback,
        }
        impl Chare for FirstByte {
            fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
                match msg.ep {
                    EP_GO => {
                        let me = ctx.me();
                        self.io.open(
                            ctx,
                            self.file,
                            self.size,
                            FileOptions::with_readers(1),
                            Callback::to_chare(me, EP_OPENED),
                        );
                    }
                    EP_OPENED => {
                        let me = ctx.me();
                        self.io.start_read_session(
                            ctx,
                            self.file,
                            0,
                            self.size,
                            SessionOptions { splinter_bytes: self.splinter, ..Default::default() },
                            Callback::to_chare(me, EP_READY),
                        );
                    }
                    EP_READY => {
                        let s: Session = msg.take();
                        let me = ctx.me();
                        // Ask for only the first 1 MiB.
                        self.io.read(ctx, &s, 0, 1 << 20, Callback::to_chare(me, EP_DATA));
                    }
                    EP_DATA => {
                        let _r: ReadResult = msg.take();
                        ctx.fire(self.done.clone(), Payload::empty());
                    }
                    other => panic!("unknown ep {other}"),
                }
            }
            impl_chare_any!();
        }

        let fut = eng.future(1);
        let c = eng.create_singleton(Pe(1), FirstByte {
            io,
            file,
            size,
            splinter,
            done: Callback::Future(fut),
        });
        eng.inject_signal(c, EP_GO);
        eng.run();
        assert!(eng.future_done(fut));
        eng.take_future(fut)[0].0
    };
    let whole = run(None);
    let splintered = run(Some(8 << 20));
    assert!(
        splintered * 4 < whole,
        "splintered first-read latency {splintered} should be ≪ whole-span {whole}"
    );
}

#[test]
fn session_close_releases_and_acks() {
    struct Closer {
        io: CkIo,
        file: FileId,
        size: u64,
        done: Callback,
    }
    impl Chare for Closer {
        fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
            match msg.ep {
                EP_GO => {
                    let me = ctx.me();
                    self.io
                        .open(
                            ctx,
                            self.file,
                            self.size,
                            FileOptions::with_readers(2),
                            Callback::to_chare(me, EP_OPENED),
                        );
                }
                EP_OPENED => {
                    let me = ctx.me();
                    self.io
                        .start_read_session(
                            ctx,
                            self.file,
                            0,
                            self.size,
                            SessionOptions::default(),
                            Callback::to_chare(me, EP_READY),
                        );
                }
                EP_READY => {
                    let s: Session = msg.take();
                    let me = ctx.me();
                    self.io.close_read_session(ctx, s.id, Callback::to_chare(me, EP_CLOSED));
                }
                EP_CLOSED => {
                    let me = ctx.me();
                    self.io.close(ctx, self.file, Callback::to_chare(me, EP_FCLOSED));
                }
                EP_FCLOSED => ctx.fire(self.done.clone(), Payload::empty()),
                other => panic!("unknown ep {other}"),
            }
        }
        impl_chare_any!();
    }
    const EP_CLOSED: Ep = 7;
    const EP_FCLOSED: Ep = 8;

    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let file = eng.core.sim_pfs_mut().create_file(16 << 20);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(1);
    let c = eng
        .create_singleton(Pe(2), Closer { io, file, size: 16 << 20, done: Callback::Future(fut) });
    eng.inject_signal(c, EP_GO);
    eng.run();
    assert!(eng.future_done(fut));
}

#[test]
fn wall_clock_real_disk_ckio_round_trip() {
    // Full CkIO stack over real files and real reader threads.
    let dir = std::env::temp_dir().join("ckio_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("real_ckio.bin");
    let size: u64 = 2 << 20;
    std::fs::write(&path, pattern::make(FileId(0), 0, size)).unwrap();

    let mut eng = Engine::new(EngineConfig::real(1, 2)).with_local_disk(2);
    let file = eng.core.local_disk_mut().register_file(&path);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(4);
    let per = size / 4;
    let cid = eng.create_array(4, &Placement::RoundRobinPes, |i| Client {
        io,
        file,
        file_size: size,
        my_offset: i as u64 * per,
        my_len: per,
        piece: 256 << 10,
        leader_for: if i == 0 { Some(4) } else { None },
        session: None,
        received: 0,
        verify: true,
        done: Callback::Future(fut),
        migrate_between_reads: None,
    });
    eng.inject_signal(ChareRef::new(cid, 0), EP_GO);
    eng.run();
    assert!(eng.future_done(fut));
}

#[test]
fn buffer_read_starts_before_clients_ask() {
    // Greedy prefetch: with a session started but no reads issued, the
    // PFS still sees the session bytes being read.
    struct OnlyStart {
        io: CkIo,
        file: FileId,
        size: u64,
    }
    impl Chare for OnlyStart {
        fn receive(&mut self, ctx: &mut Ctx<'_>, msg: Msg) {
            match msg.ep {
                EP_GO => {
                    let me = ctx.me();
                    self.io
                        .open(
                            ctx,
                            self.file,
                            self.size,
                            FileOptions::with_readers(4),
                            Callback::to_chare(me, EP_OPENED),
                        );
                }
                EP_OPENED => {
                    self.io.start_read_session(
                        ctx,
                        self.file,
                        0,
                        self.size,
                        SessionOptions::default(),
                        Callback::Ignore,
                    );
                }
                other => panic!("unknown ep {other}"),
            }
            drop(msg);
        }
        impl_chare_any!();
    }
    let mut eng = Engine::new(EngineConfig::sim(1, 2)).with_sim_pfs(PfsConfig {
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let file = eng.core.sim_pfs_mut().create_file(64 << 20);
    let io = CkIo::boot(&mut eng);
    let c = eng.create_singleton(Pe(0), OnlyStart { io, file, size: 64 << 20 });
    eng.inject_signal(c, EP_GO);
    let end = eng.run();
    // All 64 MiB were prefetched with zero client reads.
    assert_eq!(eng.core.metrics.counter("pfs.bytes_read"), 64 << 20);
    assert_eq!(eng.core.metrics.counter("ckio.reads_served"), 0);
    assert!(end > 10 * MILLIS);
}
