//! QoS-class integration tests (PR 5): the scoped-configuration API and
//! the class-weighted admission path, end to end.
//!
//! * **Class negotiation** — the session's `QosClass` reaches the owning
//!   data-plane shard *before any buffer exists*: on the `EP_SHARD_PLAN`
//!   probe for store-aware starts, and on the lightweight
//!   `EP_SHARD_ADMIT` register for concrete placements and rebinds —
//!   exactly one registration per session start, on the home shard only.
//! * **Classed admission end-to-end** — two governed sessions of
//!   different classes contend on one shard under cap 1: both classes'
//!   tickets are granted (`ckio.governor.class_granted.*`), every byte
//!   verifies, and the governor holds no residue after teardown.
//! * **Scavenger completion** — a Scavenger session sharing the shard
//!   with an Interactive one still completes (weighted dequeue is
//!   starvation-free).
//! * **Conflicting re-open** — opening an already-open file with
//!   different `FileOptions` fails with `OpenError::OptionsConflict`
//!   instead of silently keeping the first opener's options.

use ckio::amt::callback::Callback;
use ckio::amt::engine::{Engine, EngineConfig};
use ckio::ckio::director::Director;
use ckio::ckio::{
    CkIo, FileHandle, FileOptions, OpenError, QosClass, ReadResult, ReaderPlacement,
    ServiceConfig, Session, SessionId, SessionOptions,
};
use ckio::harness::experiments::assert_service_clean;
use ckio::metrics::keys;
use ckio::pfs::{pattern, FileId, PfsConfig};

const MIB: u64 = 1 << 20;

fn verified_engine(nfiles: u32, file_size: u64, cfg: ServiceConfig) -> (Engine, Vec<FileId>, CkIo) {
    let mut eng = Engine::new(EngineConfig::sim(2, 2)).with_sim_pfs(PfsConfig {
        materialize: true,
        noise_sigma: 0.0,
        ..PfsConfig::default()
    });
    let files = (0..nfiles).map(|_| eng.core.sim_pfs_mut().create_file(file_size)).collect();
    let io = CkIo::boot_with(&mut eng, cfg).expect("valid ServiceConfig");
    (eng, files, io)
}

fn open_file(eng: &mut Engine, io: &CkIo, file: FileId, size: u64, opts: FileOptions) {
    let fut = eng.future(1);
    io.open_driver(eng, file, size, opts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "open never completed");
}

fn start_session(
    eng: &mut Engine,
    io: &CkIo,
    file: FileId,
    offset: u64,
    bytes: u64,
    sopts: SessionOptions,
) -> Session {
    let fut = eng.future(1);
    io.start_session_driver(eng, file, offset, bytes, sopts, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session never became ready");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    p.take::<Session>()
}

fn close_session(eng: &mut Engine, io: &CkIo, sid: SessionId) {
    let fut = eng.future(1);
    io.close_session_driver(eng, sid, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "session close never completed");
}

fn close_file(eng: &mut Engine, io: &CkIo, file: FileId) {
    let fut = eng.future(1);
    io.close_file_driver(eng, file, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "file close never completed");
}

fn read_verified(eng: &mut Engine, io: &CkIo, s: &Session, file: FileId, offset: u64, len: u64) {
    let fut = eng.future(1);
    io.read_driver(eng, 0, s, offset, len, Callback::Future(fut));
    eng.run();
    assert!(eng.future_done(fut), "read callback never fired");
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    let r = p.take::<ReadResult>();
    assert_eq!(r.len, len);
    let bytes = r.chunk.bytes.as_ref().expect("materialized run must deliver bytes");
    assert_eq!(pattern::verify(file, offset, bytes), None, "corrupt read");
}

/// Registrations per class on every shard; the class must land on the
/// home shard only.
fn registrations(eng: &Engine, io: &CkIo, class: QosClass) -> Vec<u64> {
    (0..io.nshards).map(|s| io.shard(eng, s).class_registrations(class)).collect()
}

// ---------------------------------------------------------------------
// 1. The class rides the EP_SHARD_PLAN probe, intact
// ---------------------------------------------------------------------

#[test]
fn class_is_carried_intact_through_the_plan_probe() {
    let size = MIB;
    let (mut eng, files, io) = verified_engine(1, size, ServiceConfig::default());
    let file = files[0];
    let fopts = FileOptions {
        num_readers: Some(4),
        placement: ReaderPlacement::StoreAware { fallback: Box::new(ReaderPlacement::SpreadNodes) },
    };
    open_file(&mut eng, &io, file, size, fopts);
    let s = start_session(&mut eng, &io, file, 0, size, SessionOptions::interactive());
    let home = eng.chare::<Director>(io.director).shard_of_file(file);
    let by_shard = registrations(&eng, &io, QosClass::Interactive);
    assert_eq!(by_shard[home as usize], 1, "the plan probe must register the class");
    for (i, &c) in by_shard.iter().enumerate() {
        if i != home as usize {
            assert_eq!(c, 0, "class registration leaked onto shard {i}");
        }
    }
    // No other class was registered anywhere.
    assert!(registrations(&eng, &io, QosClass::Bulk).iter().all(|&c| c == 0));
    assert!(registrations(&eng, &io, QosClass::Scavenger).iter().all(|&c| c == 0));
    close_session(&mut eng, &io, s.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 2. Concrete placements and rebinds register via EP_SHARD_ADMIT
// ---------------------------------------------------------------------

#[test]
fn concrete_and_rebind_starts_register_their_class_via_admit() {
    let size = MIB;
    let (mut eng, files, io) = verified_engine(1, size, ServiceConfig::default());
    let file = files[0];
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));
    let home = eng.chare::<Director>(io.director).shard_of_file(file);

    // A concrete-placement (no plan probe) Bulk session registers once.
    let reuse_bulk = SessionOptions { reuse_buffers: true, ..Default::default() };
    let s1 = start_session(&mut eng, &io, file, 0, size, reuse_bulk);
    assert_eq!(registrations(&eng, &io, QosClass::Bulk)[home as usize], 1);

    // Parking and rebinding under a *different* class registers the new
    // tenant's class (the parked array may serve anyone).
    close_session(&mut eng, &io, s1.id);
    let reuse_scavenger = SessionOptions {
        class: QosClass::Scavenger,
        reuse_buffers: true,
        ..Default::default()
    };
    let s2 = start_session(&mut eng, &io, file, 0, size, reuse_scavenger);
    assert_eq!(eng.core.metrics.counter("ckio.buffer_reuse"), 1, "second start must rebind");
    assert_eq!(registrations(&eng, &io, QosClass::Scavenger)[home as usize], 1);
    // Exactly one registration per session start: Bulk stayed at 1.
    assert_eq!(registrations(&eng, &io, QosClass::Bulk)[home as usize], 1);
    read_verified(&mut eng, &io, &s2, file, 0, size);
    close_session(&mut eng, &io, s2.id);
    close_file(&mut eng, &io, file);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 3. Classed admission end-to-end under a contended cap
// ---------------------------------------------------------------------

#[test]
fn classed_sessions_share_a_capped_shard_and_both_complete_verified() {
    let size = MIB;
    let cfg = ServiceConfig {
        max_inflight_reads: Some(1),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let (mut eng, files, io) = verified_engine(2, size, cfg);
    let fopts = FileOptions::with_readers(2);
    open_file(&mut eng, &io, files[0], size, fopts.clone());
    open_file(&mut eng, &io, files[1], size, fopts);

    // Start both sessions in one scheduling window so their governed
    // greedy prefetches contend for the single ticket.
    let splinter = Some(64 << 10);
    let interactive = SessionOptions {
        class: QosClass::Interactive,
        splinter_bytes: splinter,
        read_window: 8,
        ..Default::default()
    };
    let bulk = SessionOptions {
        class: QosClass::Bulk,
        splinter_bytes: splinter,
        read_window: 8,
        ..Default::default()
    };
    let ready = eng.future(2);
    io.start_session_driver(&mut eng, files[0], 0, size, interactive, Callback::Future(ready));
    io.start_session_driver(&mut eng, files[1], 0, size, bulk, Callback::Future(ready));
    eng.run();
    assert!(eng.future_done(ready), "sessions never became ready");

    // The cap held, both classes were granted tickets, and demand was
    // genuinely deferred (the queue — hence the weighted dequeue — ran).
    assert!(eng.core.metrics.value(keys::PFS_MAX_CONCURRENT) <= 1.0);
    assert!(eng.core.metrics.counter(keys::GOV_THROTTLED) > 0);
    assert!(eng.core.metrics.counter(keys::GOV_GRANTED_INTERACTIVE) > 0);
    assert!(eng.core.metrics.counter(keys::GOV_GRANTED_BULK) > 0);
    assert_eq!(eng.core.metrics.counter(keys::GOV_GRANTED_SCAVENGER), 0);

    let sessions: Vec<Session> = eng
        .take_future(ready)
        .into_iter()
        .map(|(_, mut p)| p.take::<Session>())
        .collect();
    for s in &sessions {
        read_verified(&mut eng, &io, s, s.file, 0, size);
    }
    for s in sessions {
        close_session(&mut eng, &io, s.id);
    }
    close_file(&mut eng, &io, files[0]);
    close_file(&mut eng, &io, files[1]);
    assert_service_clean(&eng, &io);
    assert_eq!(io.governor_inflight(&eng), 0);
    assert_eq!(io.governor_queued(&eng), 0);
}

// ---------------------------------------------------------------------
// 4. Scavenger work is not starved by Interactive load
// ---------------------------------------------------------------------

#[test]
fn scavenger_session_completes_under_interactive_contention() {
    let size = MIB;
    let cfg = ServiceConfig {
        max_inflight_reads: Some(1),
        data_plane_shards: Some(1),
        ..Default::default()
    };
    let (mut eng, files, io) = verified_engine(2, size, cfg);
    let fopts = FileOptions::with_readers(2);
    open_file(&mut eng, &io, files[0], size, fopts.clone());
    open_file(&mut eng, &io, files[1], size, fopts);
    let splintered = |class: QosClass| SessionOptions {
        class,
        splinter_bytes: Some(64 << 10),
        read_window: 8,
        ..Default::default()
    };
    let ready = eng.future(2);
    io.start_session_driver(
        &mut eng,
        files[0],
        0,
        size,
        splintered(QosClass::Interactive),
        Callback::Future(ready),
    );
    io.start_session_driver(
        &mut eng,
        files[1],
        0,
        size,
        splintered(QosClass::Scavenger),
        Callback::Future(ready),
    );
    eng.run();
    assert!(eng.future_done(ready));
    // Every queued ticket was eventually granted: the scavenger's whole
    // prefetch ran (its session's bytes all left the PFS), and nothing
    // is parked in the governor.
    assert!(eng.core.metrics.counter(keys::GOV_GRANTED_SCAVENGER) > 0);
    assert_eq!(eng.core.metrics.counter("pfs.bytes_read"), 2 * size);
    assert_eq!(io.governor_inflight(&eng), 0, "tickets leaked");
    assert_eq!(io.governor_queued(&eng), 0, "scavenger demand stranded");
    let sessions: Vec<Session> = eng
        .take_future(ready)
        .into_iter()
        .map(|(_, mut p)| p.take::<Session>())
        .collect();
    for s in &sessions {
        read_verified(&mut eng, &io, s, s.file, 0, size);
    }
    for s in sessions {
        close_session(&mut eng, &io, s.id);
    }
    close_file(&mut eng, &io, files[0]);
    close_file(&mut eng, &io, files[1]);
    assert_service_clean(&eng, &io);
}

// ---------------------------------------------------------------------
// 5. Conflicting re-opens are structured errors, not silent ignores
// ---------------------------------------------------------------------

#[test]
fn reopen_with_different_file_options_is_a_conflict_error() {
    let size = MIB;
    let (mut eng, files, io) = verified_engine(1, size, ServiceConfig::default());
    let file = files[0];
    open_file(&mut eng, &io, file, size, FileOptions::with_readers(2));

    // Same options: idempotent refcounted re-open, handle delivered.
    let fut = eng.future(1);
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(2), Callback::Future(fut));
    eng.run();
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    let h = p.take::<FileHandle>();
    assert_eq!(h.opts.num_readers, Some(2));
    assert_eq!(eng.core.metrics.counter("ckio.reopens"), 1);

    // Different options: a structured conflict on the callback.
    let fut = eng.future(1);
    io.open_driver(&mut eng, file, size, FileOptions::with_readers(4), Callback::Future(fut));
    eng.run();
    let (_, mut p) = eng.take_future(fut).pop().unwrap();
    assert_eq!(p.take::<OpenError>(), OpenError::OptionsConflict);
    assert_eq!(eng.core.metrics.counter("ckio.opens_rejected"), 1);

    // The file is untouched by the rejected re-open: still readable
    // under the original options, and the refcount is exactly 2.
    let s = start_session(&mut eng, &io, file, 0, size, SessionOptions::default());
    read_verified(&mut eng, &io, &s, file, 0, size);
    close_session(&mut eng, &io, s.id);
    close_file(&mut eng, &io, file);
    close_file(&mut eng, &io, file);
    assert_eq!(eng.chare::<Director>(io.director).open_files(), 0);
    assert_service_clean(&eng, &io);
}
