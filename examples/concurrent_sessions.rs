//! Concurrent multi-session CkIO: K independent workloads, each with its
//! own read session (mixed same-file and distinct-file), open, read, and
//! close at the same time against one shared parallel file system.
//!
//! This is the scenario the multi-session lifecycle work enables: tags
//! are namespaced per session so the assemblers never confuse concurrent
//! reads, file opens are refcounted so sessions can share a file, and
//! teardown drains in-flight fetches so closing one workload never
//! strands another. The run reports aggregate delivered throughput and
//! per-read p99 latency as the session count grows, then proves the
//! teardown left nothing behind.
//!
//! ```sh
//! cargo run --release --example concurrent_sessions -- [--file-size 256MiB] [--clients 32]
//! ```

use ckio::ckio::director::Director;
use ckio::ckio::{FileOptions, ServiceConfig, SessionOptions};
use ckio::harness::experiments::{assert_service_clean, run_svc_concurrent};
use ckio::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let size = args.get_bytes_or("file-size", 256 << 20);
    let clients = args.get_or("clients", 32u32);
    let readers = args.get_or("readers", 8u32);
    let (nodes, pes) = (args.get_or("nodes", 4u32), args.get_or("pes-per-node", 8u32));

    println!(
        "{nodes} nodes x {pes} PEs; each session: {} read by {clients} clients through \
         {readers} buffer chares. Odd-numbered sessions share the previous session's file.\n",
        ckio::util::human_bytes(size),
    );
    println!(
        "{:>3}  {:>10}  {:>12}  {:>12}  {:>12}",
        "K", "agg GiB/s", "sess mean", "sess p-worst", "read p99"
    );

    let mut single = 0.0;
    let mut last = 0.0;
    for k in [1u32, 2, 4, 8] {
        let (stats, io, eng) = run_svc_concurrent(
            nodes,
            pes,
            size,
            k,
            clients,
            ServiceConfig::default(),
            FileOptions::with_readers(readers),
            SessionOptions::default(),
            42,
        );
        if k == 1 {
            single = stats.aggregate_gibs;
        }
        last = stats.aggregate_gibs;
        let mean = stats.per_session_s.iter().sum::<f64>() / k as f64;
        let worst = stats.per_session_s.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "{k:>3}  {:>10.2}  {:>11.3}s  {:>11.3}s  {:>11.4}s",
            stats.aggregate_gibs, mean, worst, stats.read_p99_s
        );

        // Teardown left nothing behind: no live sessions, no pending
        // closes, no file refs, no in-flight assemblies anywhere.
        assert_service_clean(&eng, &io);
        let director = eng.chare::<Director>(io.director);
        assert_eq!(director.open_files(), 0, "leaked file refs");
    }

    println!(
        "\n=> all sessions closed cleanly; aggregate throughput scaled {:.2}x from K=1 to K=8",
        last / single
    );
}
