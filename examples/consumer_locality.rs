//! Consumer-side locality (flow-matrix-driven consumer migration), narrated.
//!
//! Two sessions over one shared file; each session's consumers start on
//! PEs that hold none of their data, while the session's buffers are
//! pinned elsewhere — the static worst case, where every delivered piece
//! byte crosses PEs. The assembler charges each piece delivery to its
//! (consumer, source-PE) flow account; at the piece threshold the
//! director advises each consumer to migrate to its dominant source PE
//! (AMT location-managed, with hysteresis and a hard per-session
//! migration budget), and the remaining reads become PE-local.
//!
//! ```sh
//! cargo run --release --example consumer_locality
//! ```

use ckio::ckio::{ConsumerPlacement, ServiceConfig};
use ckio::harness::experiments::{assert_service_clean, run_svc_overlap, OVERLAP_SHAPE};

fn main() {
    let (nodes, pes, file_bytes, consumers, rounds) = OVERLAP_SHAPE;
    println!(
        "{nodes}x{pes} PEs, {} shared file, 2 sessions x {consumers} consumers x {rounds} rounds;",
        ckio::util::human_bytes(file_bytes)
    );
    println!("consumers on the low PEs, each session's buffers pinned to the high PEs.\n");

    let (st, io_s, eng_s) =
        run_svc_overlap(ConsumerPlacement::Static, ServiceConfig::default(), false, 42);
    assert_service_clean(&eng_s, &io_s);
    let flow = ConsumerPlacement::FlowAware { piece_threshold: 2, migration_budget: 4 };
    let (fa, io_f, eng_f) = run_svc_overlap(flow, ServiceConfig::default(), false, 42);
    assert_service_clean(&eng_f, &io_f);

    let mib = |b: u64| b as f64 / (1u64 << 20) as f64;
    println!(
        "{:>12}  {:>13}  {:>13}  {:>7}  {:>10}",
        "placement", "same_pe", "cross_pe", "advised", "migrations"
    );
    println!(
        "{:>12}  {:>9.2} MiB  {:>9.2} MiB  {:>7}  {:>10}",
        "static",
        mib(st.same_pe_piece_bytes),
        mib(st.cross_pe_piece_bytes),
        st.advised,
        st.migrations
    );
    println!(
        "{:>12}  {:>9.2} MiB  {:>9.2} MiB  {:>7}  {:>10}",
        "flow-aware",
        mib(fa.same_pe_piece_bytes),
        mib(fa.cross_pe_piece_bytes),
        fa.advised,
        fa.migrations
    );
    let reduction = 1.0 - fa.cross_pe_piece_bytes as f64 / st.cross_pe_piece_bytes.max(1) as f64;
    println!(
        "\ncross-PE piece bytes cut by {:.0}% ({} flow reports; hysteresis kept every",
        reduction * 100.0,
        fa.flow_reports
    );
    println!("consumer at its dominant source after one move — no ping-pong), and both");
    println!("runs tore down clean: no flow matrices, accounts, or windows left behind.");
    assert!(reduction >= 0.5, "flow-aware placement must at least halve cross-PE piece bytes");
}
