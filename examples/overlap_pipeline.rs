//! Pipeline parallelism via a *series of read sessions* (paper §III-A).
//!
//! The paper's motivating pattern: n workers process a file in
//! block-cyclic fashion; a worker must finish computing on block r
//! before consuming block r+1, and the file is processed one *session*
//! per round (each session covers the n workers' blocks of that round —
//! this is also how a file that cannot fit in memory is read
//! chunk-by-chunk). Because sessions prefetch greedily and reads are
//! split-phase, the leader can start session r+1 while everyone is still
//! computing on round r — input time disappears into compute time.
//!
//! This example runs the same workload with that lookahead on and off
//! and reports how much of the input time was hidden.
//!
//! ```sh
//! cargo run --release --example overlap_pipeline
//! ```

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef, CollectionId};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::time::{self, MILLIS};
use ckio::amt::topology::{Pe, Placement};
use ckio::ckio::{CkIo, FileOptions, ReadResult, Session, SessionOptions};
use ckio::impl_chare_any;
use ckio::pfs::{FileId, PfsConfig};

const N_WORKERS: u32 = 8;
const BLOCK: u64 = 32 << 20;
const ROUNDS: u32 = 6;
/// Modeled compute per block (~ processing 32 MiB).
const COMPUTE_PER_BLOCK: u64 = 60 * MILLIS;

// Leader EPs.
const EP_L_GO: Ep = 1;
const EP_L_OPENED: Ep = 2;
const EP_L_SESSION_READY: Ep = 3;
const EP_L_ROUND_DONE: Ep = 4;
// Worker EPs.
const EP_W_SESSION: Ep = 10;
const EP_W_DATA: Ep = 11;
const EP_W_COMPUTED: Ep = 12;

/// Orchestrates the rounds: one read session per round of n blocks.
struct Leader {
    io: CkIo,
    file: FileId,
    file_size: u64,
    workers: CollectionId,
    lookahead: bool,
    sessions_started: u32,
    rounds_done: u32,
    done_count: u32,
    finished: Callback,
}

impl Leader {
    fn start_session(&mut self, ctx: &mut Ctx<'_>) {
        if self.sessions_started >= ROUNDS {
            return;
        }
        let r = self.sessions_started;
        self.sessions_started += 1;
        let me = ctx.me();
        let off = r as u64 * N_WORKERS as u64 * BLOCK;
        self.io.start_read_session(
            ctx,
            self.file,
            off,
            N_WORKERS as u64 * BLOCK,
            SessionOptions::default(),
            Callback::to_chare(me, EP_L_SESSION_READY),
        );
    }
}

impl Chare for Leader {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_L_GO => {
                let me = ctx.me();
                let (io, file, size) = (self.io, self.file, self.file_size);
                io.open(
                    ctx,
                    file,
                    size,
                    FileOptions::with_readers(8),
                    Callback::to_chare(me, EP_L_OPENED),
                );
            }
            EP_L_OPENED => self.start_session(ctx),
            EP_L_SESSION_READY => {
                let s: Session = msg.take();
                // Hand the round's session to every worker.
                for w in 0..N_WORKERS {
                    ctx.send(ChareRef::new(self.workers, w), EP_W_SESSION, s);
                }
                // Lookahead: kick the *next* round's prefetch immediately,
                // so it loads while the workers compute on this round.
                if self.lookahead {
                    self.start_session(ctx);
                }
            }
            EP_L_ROUND_DONE => {
                self.done_count += 1;
                if self.done_count == N_WORKERS {
                    self.done_count = 0;
                    self.rounds_done += 1;
                    if self.rounds_done == ROUNDS {
                        let f = self.finished.clone();
                        ctx.fire(f, Payload::empty());
                    } else if !self.lookahead {
                        // Only now fetch the next round.
                        self.start_session(ctx);
                    }
                }
            }
            other => panic!("Leader: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// Processes one block per round; must finish round r before r+1.
struct Worker {
    io: CkIo,
    index: u32,
    leader: Option<ChareRef>,
    /// Sessions delivered but not yet consumed (FIFO by round).
    pending: std::collections::VecDeque<Session>,
    computing: bool,
}

impl Worker {
    fn maybe_consume(&mut self, ctx: &mut Ctx<'_>) {
        if self.computing {
            return;
        }
        let Some(s) = self.pending.pop_front() else { return };
        self.computing = true;
        let off = s.offset + self.index as u64 * BLOCK;
        let me = ctx.me();
        let io = self.io;
        io.read(ctx, &s, off, BLOCK, Callback::to_chare(me, EP_W_DATA));
    }
}

impl Chare for Worker {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_W_SESSION => {
                let s: Session = msg.take();
                self.pending.push_back(s);
                self.maybe_consume(ctx);
            }
            EP_W_DATA => {
                let r: ReadResult = msg.take();
                debug_assert_eq!(r.len, BLOCK);
                ctx.charge("pipeline.compute", COMPUTE_PER_BLOCK);
                let me = ctx.me();
                ctx.signal(me, EP_W_COMPUTED);
            }
            EP_W_COMPUTED => {
                self.computing = false;
                ctx.signal(self.leader.unwrap(), EP_L_ROUND_DONE);
                self.maybe_consume(ctx);
            }
            other => panic!("Worker: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

fn run(lookahead: bool) -> (f64, f64) {
    let file_size = N_WORKERS as u64 * ROUNDS as u64 * BLOCK;
    let mut eng = Engine::new(EngineConfig::sim(2, 4)).with_sim_pfs(PfsConfig::default());
    let file = eng.core.sim_pfs_mut().create_file(file_size);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(1);
    let workers = eng.create_array(N_WORKERS, &Placement::RoundRobinPes, |i| Worker {
        io,
        index: i,
        leader: None,
        pending: Default::default(),
        computing: false,
    });
    let leader = eng.create_singleton(Pe(0), Leader {
        io,
        file,
        file_size,
        workers,
        lookahead,
        sessions_started: 0,
        rounds_done: 0,
        done_count: 0,
        finished: Callback::Future(fut),
    });
    for i in 0..N_WORKERS {
        eng.chare_mut::<Worker>(ChareRef::new(workers, i)).leader = Some(leader);
    }
    eng.inject_signal(leader, EP_L_GO);
    let end = eng.run();
    assert!(eng.future_done(fut));
    let compute = eng.core.metrics.duration("pipeline.compute");
    (time::to_secs(end), time::to_secs(compute))
}

fn main() {
    println!(
        "block-cyclic pipeline: {N_WORKERS} workers x {ROUNDS} rounds of {} blocks \
         ({} total), one read session per round, {} modeled compute per block\n",
        ckio::util::human_bytes(BLOCK),
        ckio::util::human_bytes(N_WORKERS as u64 * ROUNDS as u64 * BLOCK),
        time::human(COMPUTE_PER_BLOCK),
    );
    let (plain_s, compute_s) = run(false);
    let (pipe_s, _) = run(true);
    let compute_per_pe = compute_s / 8.0;
    println!("  sessions started only when needed: {plain_s:.3}s");
    println!("  next session prefetched during compute: {pipe_s:.3}s");
    println!("  pure compute (per PE): {compute_per_pe:.3}s");
    let hidden = (plain_s - pipe_s) / (plain_s - compute_per_pe);
    println!(
        "\n=> {:.0}% of the input time was hidden by overlapping the next session's",
        hidden * 100.0
    );
    println!("   greedy prefetch with the current round's computation (paper SecIII-A).");
    assert!(pipe_s < plain_s, "pipelining must help");
}
