//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! 1. Generates a real Tipsy file on local disk (synthetic Plummer-ish
//!    initial conditions, quantized fixed-point records).
//! 2. Boots the runtime in **wall-clock mode**: real `pread`s on helper
//!    reader threads, real PJRT executables compiled from the AOT
//!    JAX/Pallas artifacts (`make artifacts` first).
//! 3. Runs the mini-ChaNGa input phase through CkIO (and, for
//!    comparison, the unopt and hand-optimized schemes), then `--steps`
//!    gravity steps — decode/permute/moments and the tiled all-pairs
//!    kernel all execute inside the lowered HLO.
//! 4. Reports input throughput per scheme and the per-step |acc| curve
//!    (the N-body analogue of a loss curve).
//!
//! ```sh
//! make artifacts
//! cargo run --release --example changa_e2e -- [--nbodies 1048576] [--tp 512] [--steps 5]
//! ```

use ckio::apps::changa::driver::{run_changa_e2e, Scheme};
use ckio::apps::changa::tipsy;
use ckio::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // 1M particles = 32 MiB of records; 512 TreePieces = 64x
    // over-decomposition on the 8 multiplexed PEs; ~2k particles/piece.
    let nbodies = args.get_or("nbodies", 1u64 << 20);
    let n_tp = args.get_or("tp", 512u32);
    let steps = args.get_or("steps", 3u32);
    let threads = args.get_or("reader-threads", 4usize);
    let artifact_dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));

    let dir = std::env::temp_dir().join("ckio_e2e");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("plummer_{nbodies}.tipsy"));
    if !path.exists() {
        println!("generating {} particles -> {}", nbodies, path.display());
        let t = std::time::Instant::now();
        tipsy::write_file(&path, nbodies, 0xC0FFEE)?;
        println!("  wrote {} in {:.1}s", ckio::util::human_bytes(std::fs::metadata(&path)?.len()),
                 t.elapsed().as_secs_f64());
    }

    let file_bytes = std::fs::metadata(&path)?.len();
    println!(
        "\n=== input phase: {} TreePieces reading {} ===",
        n_tp,
        ckio::util::human_bytes(file_bytes)
    );
    let mut ckio_report = None;
    for scheme in [Scheme::Unopt, Scheme::HandOpt, Scheme::CkIo] {
        let rep = run_changa_e2e(&path, n_tp, scheme, 0, threads, &artifact_dir)?;
        println!(
            "  {:9} input {:.3}s ({:.2} GiB/s incl. ingest-artifact decode of every piece)",
            scheme.label(),
            rep.input_secs,
            file_bytes as f64 / (1u64 << 30) as f64 / rep.input_secs,
        );
        if scheme == Scheme::CkIo {
            ckio_report = Some(rep);
        }
    }
    drop(ckio_report);

    println!("\n=== compute phase: {} gravity steps (PJRT, Pallas kernel) ===", steps);
    let rep = run_changa_e2e(&path, n_tp, Scheme::CkIo, steps, threads, &artifact_dir)?;
    println!("  input (ckio): {:.3}s", rep.input_secs);
    for (i, (an, st)) in rep.acc_norms.iter().zip(rep.step_secs.iter()).enumerate() {
        println!("  step {i}: sum|acc| = {an:.4e}   ({st:.2}s wall)");
    }
    anyhow::ensure!(
        rep.acc_norms.iter().all(|a| a.is_finite() && *a > 0.0),
        "acc curve must stay finite"
    );
    println!("\nOK: all {} layers composed (rust coordinator -> CkIO -> PJRT artifacts).", 3);
    Ok(())
}
