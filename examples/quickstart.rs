//! Quickstart: the CkIO API in ~80 lines.
//!
//! Boots a simulated 2-node × 4-PE cluster with a Lustre-like PFS, puts a
//! 64 MiB file on it, and has 32 over-decomposed client chares (8× more
//! clients than PEs) read it through a CkIO session with verified
//! end-to-end data integrity.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ckio::amt::callback::Callback;
use ckio::amt::chare::{Chare, ChareRef};
use ckio::amt::engine::{Ctx, Engine, EngineConfig};
use ckio::amt::msg::{Ep, Msg, Payload};
use ckio::amt::time;
use ckio::amt::topology::Placement;
use ckio::ckio::{CkIo, FileOptions, ReadResult, Session, SessionOptions};
use ckio::impl_chare_any;
use ckio::pfs::{pattern, FileId, PfsConfig};

const EP_GO: Ep = 1;
const EP_OPENED: Ep = 2;
const EP_READY: Ep = 3;
const EP_DATA: Ep = 4;

const FILE_SIZE: u64 = 64 << 20;
const N_CLIENTS: u32 = 32;

struct Client {
    io: CkIo,
    file: FileId,
    index: u32,
    peers: ckio::amt::chare::CollectionId,
    done: Callback,
}

impl Chare for Client {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        let me = ctx.me();
        match msg.ep {
            // Client 0 opens the file and starts a session for everyone.
            EP_GO => self.io.open(ctx, self.file, FILE_SIZE, FileOptions::default(),
                                  Callback::to_chare(me, EP_OPENED)),
            EP_OPENED => self.io.start_read_session(ctx, self.file, 0, FILE_SIZE,
                                                    SessionOptions::default(),
                                                    Callback::to_chare(me, EP_READY)),
            EP_READY => {
                let s: Session = msg.take();
                if self.index == 0 {
                    for j in 1..N_CLIENTS {
                        ctx.send(ChareRef::new(self.peers, j), EP_READY, s);
                    }
                }
                // Read my disjoint slice (split-phase; the PE keeps going).
                let per = FILE_SIZE / N_CLIENTS as u64;
                self.io.read(ctx, &s, self.index as u64 * per, per,
                             Callback::to_chare(me, EP_DATA));
            }
            EP_DATA => {
                let r: ReadResult = msg.take();
                // Verify every byte against the deterministic pattern.
                let bytes = r.chunk.bytes.as_ref().expect("materialized");
                assert_eq!(pattern::verify(self.file, r.offset, bytes), None, "corruption!");
                let done = self.done.clone();
                ctx.fire(done, Payload::new(r.len));
            }
            other => panic!("unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

fn main() {
    let mut eng = Engine::new(EngineConfig::sim(2, 4))
        .with_sim_pfs(PfsConfig { materialize: true, ..PfsConfig::default() });
    let file = eng.core.sim_pfs_mut().create_file(FILE_SIZE);
    let io = CkIo::boot(&mut eng);

    let fut = eng.future(N_CLIENTS);
    let clients = eng.create_array(N_CLIENTS, &Placement::RoundRobinPes, |i| Client {
        io,
        file,
        index: i,
        peers: ckio::amt::chare::CollectionId(u32::MAX),
        done: Callback::Future(fut),
    });
    for i in 0..N_CLIENTS {
        eng.chare_mut::<Client>(ChareRef::new(clients, i)).peers = clients;
    }

    eng.inject_signal(ChareRef::new(clients, 0), EP_GO);
    let end = eng.run();
    assert!(eng.future_done(fut));
    let total: u64 = eng.take_future(fut).into_iter().map(|(_, mut p)| p.take::<u64>()).sum();

    println!("read + verified {} through CkIO with {N_CLIENTS} clients on 8 PEs",
             ckio::util::human_bytes(total));
    println!("modeled cluster time: {} ({:.2} GiB/s)",
             time::human(end),
             total as f64 / (1u64 << 30) as f64 / time::to_secs(end));
    println!("reads served: {}, buffer fetches: {}, messages: {}",
             eng.core.metrics.counter("ckio.reads_served"),
             eng.core.metrics.counter("ckio.fetches"),
             eng.core.metrics.counter("amt.msgs_sent"));
}
