//! Store-aware reader placement (PR 4): plan-then-create session start.
//!
//! The paper's Fig. 12 shows CkIO's locality win — moving consumers to
//! the PE that already holds their bytes turns cross-node reads into
//! local copies. Since PR 2 the span store knows exactly where every
//! file's bytes are resident, and since PR 3 one probe to one shard
//! answers it for any range. This example applies that at *session
//! start*: with `ReaderPlacement::StoreAware`, the director probes the
//! shard (`EP_SHARD_PLAN`) before creating the buffer array and places
//! each buffer chare on the PE of its dominant peer source.
//!
//! The workload: K successive sessions over one file, each window
//! shifted against the first session's partition so a buffer's *index*
//! no longer tells you where its bytes live. Index-based placement
//! (SpreadNodes) then peer-fetches mostly across PEs; store-aware
//! placement follows the data and the cross-PE bytes collapse to zero.
//!
//! ```sh
//! cargo run --release --example locality_sessions -- [--file-size 4MiB] [--k 4]
//! ```

use ckio::ckio::ReaderPlacement;
use ckio::harness::experiments::{assert_service_clean, run_svc_locality, store_aware_spread};

fn main() {
    let args = ckio::util::cli::Args::from_env();
    let size = args.get_bytes_or("file-size", 4 << 20);
    let k = args.get_or("k", 4u32);
    let readers = args.get_or("readers", 8u32);
    let (nodes, pes) = (args.get_or("nodes", 2u32), args.get_or("pes-per-node", 4u32));

    println!(
        "{nodes} nodes x {pes} PEs; K = {k} successive overlapping sessions over ONE {} file, \
         {readers} readers each.\n",
        ckio::util::human_bytes(size),
    );
    println!(
        "{:>12}  {:>12}  {:>13}  {:>11}  {:>8}  {:>9}",
        "placement", "same_pe_KiB", "cross_pe_KiB", "cross_share", "planned", "degraded"
    );

    let mut cross = Vec::new();
    for (label, placement) in
        [("store_aware", store_aware_spread()), ("spread", ReaderPlacement::SpreadNodes)]
    {
        let (st, io, eng) = run_svc_locality(nodes, pes, size, k, readers, placement, 42);
        assert_service_clean(&eng, &io);
        let total = (st.same_pe_fetch_bytes + st.cross_pe_fetch_bytes).max(1);
        println!(
            "{:>12}  {:>12}  {:>13}  {:>11.3}  {:>8}  {:>9}",
            label,
            st.same_pe_fetch_bytes >> 10,
            st.cross_pe_fetch_bytes >> 10,
            st.cross_pe_fetch_bytes as f64 / total as f64,
            st.planned,
            st.degraded,
        );
        cross.push(st.cross_pe_fetch_bytes);
    }

    // The placement claim, enforced: following the store must strictly
    // reduce cross-PE peer-fetch traffic for the same workload (and for
    // this aligned shape it eliminates it).
    let (sa, sp) = (cross[0], cross[1]);
    assert!(
        sa < sp,
        "store-aware placement ({sa} cross-PE bytes) must beat spread placement ({sp})"
    );
    println!(
        "\n=> plan-then-create turned {} KiB of cross-PE peer fetches into same-PE copies.",
        (sp - sa) >> 10,
    );
}
