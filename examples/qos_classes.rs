//! Per-session QoS classes (PR 5): scoped configuration in action.
//!
//! Configuration now has three explicit scopes — `ServiceConfig` booted
//! once (`CkIo::boot_with`), `FileOptions` at `open`, and
//! `SessionOptions` at `startReadSession` — so a session can finally say
//! *who it is*: `Interactive`, `Bulk`, or `Scavenger`. The class rides
//! the session-start probe to the owning data-plane shard and every
//! admission ticket the session's buffers request; under a saturated
//! admission cap the governor dequeues deferred demand by weighted
//! deficit round-robin (8 : 2 : 1), so Interactive sessions drain first
//! while nothing is starved.
//!
//! The run: Interactive and Bulk sessions contending on ONE governed
//! shard under a tight cap, classed vs the classless (all-Bulk)
//! baseline. Expect the Interactive p50 session makespan to drop while
//! every Bulk session still completes and the governor quiesces empty.
//!
//! ```sh
//! cargo run --release --example qos_classes
//! ```

use ckio::harness::experiments::{qos_pair, QOS_SHAPE};

fn main() {
    let (nodes, pes, size, ni, nb, clients, cap) = QOS_SHAPE;
    println!(
        "{nodes} nodes x {pes} PEs; {ni} Interactive + {nb} Bulk sessions over distinct {} \
         files, {clients} clients each, ONE governed shard, cap {cap}.\n",
        ckio::util::human_bytes(size),
    );
    println!(
        "{:>10}  {:>12}  {:>12}  {:>12}  {:>12}  {:>12}",
        "mode", "int_p50_ms", "bulk_p50_ms", "bulk_max_ms", "granted_int", "granted_bulk"
    );

    let (classed, classless) = qos_pair(42);
    for (label, st) in [("classed", &classed), ("classless", &classless)] {
        println!(
            "{label:>10}  {:>12.3}  {:>12.3}  {:>12.3}  {:>12}  {:>12}",
            st.interactive_p50_s * 1e3,
            st.bulk_p50_s * 1e3,
            st.bulk_max_s * 1e3,
            st.granted_interactive,
            st.granted_bulk,
        );
    }

    // The QoS claim, enforced: Interactive p50 improves under classes…
    assert!(
        classed.interactive_p50_s < classless.interactive_p50_s,
        "classed interactive p50 ({:.4}s) must beat classless ({:.4}s)",
        classed.interactive_p50_s,
        classless.interactive_p50_s
    );
    // …while Bulk completes and the governor holds no residue.
    assert_eq!(classed.bulk_s.len(), nb as usize, "every bulk session must finish");
    assert_eq!(classed.governor_inflight, 0, "tickets leaked");
    assert_eq!(classed.governor_queued, 0, "demand stranded");

    println!(
        "\n=> weighted-fair admission cut the interactive p50 by {:.2}x with no bulk starvation.",
        classless.interactive_p50_s / classed.interactive_p50_s,
    );
}
