//! Migration for locality: the paper's Figs. 10–12 experiment, narrated.
//!
//! Two nodes, one PE each, two buffer chares (one per node), two clients.
//! Each client wants the data held by the *other* node's buffer chare:
//! reads cross the interconnect. The clients then migrate to the data —
//! carrying their open session handles with them, which is the
//! correctness claim — and repeat an identical-size read, now node-local.
//!
//! ```sh
//! cargo run --release --example migration_locality -- [--file-size 1GiB]
//! ```

use ckio::amt::time;
use ckio::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let sizes: Vec<u64> = match args.get("file-size") {
        Some(s) => vec![ckio::util::parse_bytes(s).expect("--file-size")],
        None => (6..=12).map(|e| 1u64 << (20 + e)).collect(),
    };
    println!("2 nodes x 1 PE; buffer b0 on node0 holds the first half, b1 on node1 the second.");
    println!("c0 (node0) wants b1's half; c1 (node1) wants b0's half. Then both migrate.\n");
    println!("{:>10}  {:>12}  {:>12}  {:>8}", "file", "pre-migrate", "post-migrate", "speedup");
    for size in sizes {
        // The driver inside the harness runs: warmup read (absorbs the
        // prefetch), timed cross-node read, migration, timed local read.
        let table = one(size);
        let (pre, post) = table;
        println!(
            "{:>10}  {:>12}  {:>12}  {:>7.2}x",
            ckio::util::human_bytes(size),
            time::human(time::from_secs(pre)),
            time::human(time::from_secs(post)),
            pre / post
        );
    }
    println!("\nBoth reads returned correct data across the migration (location-managed");
    println!("callbacks chase the chare), and moving the work to the data pays off");
    println!("increasingly with size — paper Fig. 12.");
}

fn one(size: u64) -> (f64, f64) {
    // Reuse the Fig.12 driver for a single size.
    let t = ckio::harness::experiments::fig12_migration_single(size, 42);
    (t.0, t.1)
}
