//! The sharded data plane (PR 3): session churn over distinct files
//! scales with the shard count instead of queueing on one coordinator.
//!
//! PR 2 put the span store and the admission governor on the director
//! singleton: every claim registration and every admission ticket of
//! every session serialized through one chare on one PE. This run
//! reproduces that bottleneck (shards = 1) and then sweeps the
//! data-plane shard count: K sessions over K *distinct* files, on a
//! deliberately control-plane-heavy PFS shape (tiny cheap reads), so
//! coordination — not the disks — bounds the run. The I/O work is
//! bit-for-bit identical across rows; only where the coordination
//! executes changes.
//!
//! Expect the makespan to drop monotonically until every file has its
//! own shard, and the max-vs-mean per-shard message counts to show the
//! load spreading.
//!
//! ```sh
//! cargo run --release --example sharded_churn -- [--file-size 512KiB] [--k 8]
//! ```

use ckio::harness::experiments::run_svc_churn;

fn main() {
    let args = ckio::util::cli::Args::from_env();
    let size = args.get_bytes_or("file-size", 512 << 10);
    let k = args.get_or("k", 8u32);
    let clients = args.get_or("clients", 4u32);
    let (nodes, pes) = (args.get_or("nodes", 4u32), args.get_or("pes-per-node", 8u32));

    println!(
        "{nodes} nodes x {pes} PEs; K = {k} sessions over {k} DISTINCT {} files, \
         {clients} clients each, governed, 4 KiB splinters.\n",
        ckio::util::human_bytes(size),
    );
    println!(
        "{:>6}  {:>12}  {:>15}  {:>16}  {:>9}",
        "shards", "makespan_ms", "shard_msgs_max", "shard_msgs_mean", "imbalance"
    );

    let mut first = None;
    let mut last = None;
    let mut last_shards = 1u32;
    for shards in [1u32, 2, 4, 8, 16] {
        let (st, io, eng) = run_svc_churn(nodes, pes, size, k, clients, shards, 42);
        ckio::harness::experiments::assert_service_clean(&eng, &io);
        println!(
            "{:>6}  {:>12.3}  {:>15}  {:>16.1}  {:>8.2}x",
            st.shards,
            st.makespan_s * 1e3,
            st.shard_msgs_max,
            st.shard_msgs_mean,
            st.shard_msgs_max as f64 / st.shard_msgs_mean.max(1.0),
        );
        if st.shards == 1 {
            first = Some(st.makespan_s);
        } else {
            // The widest spread run so far (rows sweep upward, so the
            // final value is the most-sharded configuration).
            last = Some(st.makespan_s);
            last_shards = st.shards;
        }
    }

    // The sharding claim, enforced: spreading the data plane must
    // clearly beat the single-shard (PR 2) plane. Only meaningful when
    // there is something to spread (k > 1) and the topology let the
    // sweep actually spread it (≥ 4 active shards; on a tiny engine
    // every row clamps toward one shard and both configurations sit on
    // the same I/O floor).
    let t1 = first.expect("shards=1 row");
    let tk = last.unwrap_or(t1);
    if k > 1 && last_shards >= 4.min(k) {
        assert!(
            tk < 0.8 * t1,
            "sharded data plane ({tk:.4}s) must clearly beat the singleton ({t1:.4}s)"
        );
    }
    println!(
        "\n=> the director is a lifecycle coordinator; the data plane scales with its shards \
         ({:.2}x faster fully sharded).",
        t1 / tk
    );
}
