//! The collective output plane (PR 10): producers scatter pieces to
//! per-PE write assemblers, write buffers coalesce them into
//! stripe-aligned extents, and the PFS sees a handful of large write
//! RPCs instead of one per piece.
//!
//! The run shows the three headline behaviors side by side:
//!
//! * **Aggregation** — the naive every-producer-writes baseline pays
//!   one PFS RPC per piece; the write plane pays one per stripe.
//! * **Read-after-write residency** — a closed write session leaves
//!   its bytes parked as store claims, so a following read session
//!   over the same range never touches the PFS (0 read bytes) and
//!   every delivered byte verifies against what was written.
//! * **Lazy durability** — `WriteOptions::lazy()` parks the close
//!   *dirty*: the PFS write happens only when the store evicts or
//!   purges the parked span (a forced writeback); nothing is lost.
//!
//! ```sh
//! cargo run --release --example write_then_read -- [--file-size 8MiB] [--producers 8]
//! ```

use ckio::ckio::{FileOptions, ServiceConfig, WriteOptions};
use ckio::harness::experiments::{assert_service_clean, run_naive_write, run_svc_rw};
use ckio::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let size = args.get_bytes_or("file-size", 8 << 20);
    let producers = args.get_or("producers", 8u32);
    let piece = args.get_bytes_or("piece", 64 << 10);
    let (nodes, pes) = (args.get_or("nodes", 2u32), args.get_or("pes-per-node", 4u32));

    println!(
        "{nodes} nodes x {pes} PEs; {producers} producers scatter one {} file in {} pieces.\n",
        ckio::util::human_bytes(size),
        ckio::util::human_bytes(piece),
    );

    // Baseline: every producer writes each piece straight to the PFS.
    let (naive_rpcs, naive_bytes, naive_s, _) =
        run_naive_write(nodes, pes, size, producers, piece, 42);
    println!(
        "naive    : {naive_rpcs:>5} write RPCs, {} written, {:.3} ms",
        ckio::util::human_bytes(naive_bytes),
        naive_s * 1e3,
    );

    // The write plane: same scatter, coalesced into 1 MiB stripes,
    // flushed through the barrier, then read back.
    let (st, io, eng) = run_svc_rw(
        nodes,
        pes,
        size,
        producers,
        piece,
        ServiceConfig::default(),
        FileOptions::with_readers(4),
        WriteOptions::default(),
        true,
        true,
        0.0,
        42,
    );
    assert_service_clean(&eng, &io);
    let reduction = naive_rpcs as f64 / st.pfs_write_rpcs.max(1) as f64;
    println!(
        "ckio     : {:>5} write RPCs ({reduction:.1}x fewer), {} written, {:.3} ms",
        st.pfs_write_rpcs,
        ckio::util::human_bytes(st.pfs_bytes_written),
        st.write_makespan_s * 1e3,
    );
    println!(
        "read-back: {} from residency, {} from the PFS, {:.3} ms",
        ckio::util::human_bytes(st.store_hit_bytes),
        ckio::util::human_bytes(st.rw_pfs_read_bytes),
        st.read_makespan_s * 1e3,
    );
    assert_eq!(st.rw_pfs_read_bytes, 0, "read-after-write touched the PFS");
    assert!(reduction >= 4.0, "aggregation must beat naive by >= 4x, got {reduction:.2}");

    // Lazy durability: close parks dirty; the file close purges the
    // park and forces the writeback.
    let (st, io, eng) = run_svc_rw(
        nodes,
        pes,
        size,
        producers,
        piece,
        ServiceConfig::default(),
        FileOptions::with_readers(4),
        WriteOptions::lazy(),
        false,
        true,
        0.0,
        43,
    );
    assert_service_clean(&eng, &io);
    println!(
        "lazy     : {} parked dirty at close, {} forced writebacks flushed {}, \
         read-back still {} from the PFS",
        ckio::util::human_bytes(st.outcome.dirty_bytes),
        st.dirty_writebacks,
        ckio::util::human_bytes(st.dirty_writeback_bytes),
        ckio::util::human_bytes(st.rw_pfs_read_bytes),
    );
    assert_eq!(st.rw_pfs_read_bytes, 0);
    assert_eq!(st.dirty_writeback_bytes, size, "the purge must write back every dirty byte");

    println!("\n=> the PFS sees stripes, not pieces; readers-after-writers see residency.");
}
