//! The shared resident-data plane (PR 2): K concurrent sessions over ONE
//! file read it from the parallel file system approximately once.
//!
//! Before the span store, every session prefetched its full range
//! independently — K same-file sessions meant K× the PFS traffic. Now
//! the director registers every buffer chare's span as a *claim*; later
//! sessions peer-fetch claimed slots from the owning buffers (waiting on
//! their in-flight greedy reads instead of duplicating them), so the
//! bytes cross the PFS wire once and fan out over the much faster
//! interconnect.
//!
//! The run also demonstrates the admission governor: capping aggregate
//! in-flight PFS reads sequences K sessions' prefetch instead of letting
//! them interleave at the OSTs.
//!
//! ```sh
//! cargo run --release --example shared_store -- [--file-size 256MiB] [--clients 32]
//! ```

use ckio::ckio::director::Director;
use ckio::ckio::{FileOptions, ServiceConfig, SessionOptions};
use ckio::harness::experiments::{assert_service_clean, run_svc_shared};
use ckio::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let size = args.get_bytes_or("file-size", 256 << 20);
    let clients = args.get_or("clients", 32u32);
    let readers = args.get_or("readers", 8u32);
    let (nodes, pes) = (args.get_or("nodes", 4u32), args.get_or("pes-per-node", 8u32));

    println!(
        "{nodes} nodes x {pes} PEs; K sessions, ALL over one {} file, {clients} clients \
         and {readers} buffer chares each.\n",
        ckio::util::human_bytes(size),
    );
    println!(
        "{:>3}  {:>10}  {:>10}  {:>10}  {:>10}",
        "K", "PFS read", "pfs ratio", "store hit", "agg GiB/s"
    );

    let mut base = 0.0f64;
    for k in [1u32, 2, 4, 8] {
        let (st, io, eng) = run_svc_shared(
            nodes,
            pes,
            size,
            k,
            clients,
            ServiceConfig::default(),
            FileOptions::with_readers(readers),
            SessionOptions::default(),
            42,
        );
        if k == 1 {
            base = st.pfs_bytes_read as f64;
        }
        let ratio = st.pfs_bytes_read as f64 / base;
        println!(
            "{k:>3}  {:>10}  {:>9.2}x  {:>10}  {:>10.2}",
            ckio::util::human_bytes(st.pfs_bytes_read),
            ratio,
            ckio::util::human_bytes(st.store_hit_bytes),
            st.aggregate_gibs,
        );
        // The dedup claim, enforced: K same-file sessions must stay near
        // one file's worth of PFS traffic, not K of them.
        assert!(
            ratio <= 1.25,
            "K={k} same-file sessions read {ratio:.2}x the PFS bytes of one session: \
             the resident-data plane is broken"
        );
        assert_service_clean(&eng, &io);
        let director = eng.chare::<Director>(io.director);
        assert_eq!(director.open_files(), 0, "leaked file refs");
    }

    // Admission control: cap aggregate in-flight PFS reads and watch the
    // governor sequence K = 4 sessions' prefetch.
    let cfg = ServiceConfig { max_inflight_reads: Some(readers), ..Default::default() };
    let sopts = SessionOptions { splinter_bytes: Some(4 << 20), ..Default::default() };
    let (st, io, eng) = run_svc_shared(
        nodes,
        pes,
        size,
        4,
        clients,
        cfg,
        FileOptions::with_readers(readers),
        sopts,
        42,
    );
    assert_service_clean(&eng, &io);
    let peak = eng.core.metrics.value(ckio::metrics::keys::PFS_MAX_CONCURRENT);
    assert!(
        peak <= readers as f64,
        "governor cap {readers} violated: PFS saw {peak:.0} concurrent reads"
    );
    println!(
        "\ngoverned (cap {readers} reads in flight): K=4 makespan {:.3}s, \
         {} reads throttled, PFS peak concurrency {peak:.0}",
        st.makespan_s,
        st.governor_throttled,
    );

    println!("=> same-file sessions share one prefetch; the PFS sees the file once.");
}
